//! MAEVE — Moments of Attributes Estimated on Vertices Efficiently (§4.2).
//!
//! One pass.  Per-vertex triangle counts `|T_G(v)|` and 3-path-endpoint
//! counts `|P_G(v)|` are estimated with the reservoir scheme; degrees are
//! exact.  Theorem 3 turns (d, T, P) into the five NetSimile-style
//! features, and four moments (mean, std, skew, excess kurtosis) aggregate
//! each feature over the vertices — a 20-dim descriptor.

use crate::checkpoint::{Dec, Enc};
use crate::util::rng::Pcg64;

use super::{Budget, GraphDescriptor};
use crate::graph::adjacency::SampleGraph;
use crate::graph::stream::EdgeStream;
use crate::graph::Graph;
use crate::linalg::moments::maeve_layout;
use crate::sampling::window::{EdgeRing, VertexCreditLog};
use crate::sampling::{
    sample_inclusion_probability, Backend, EstimatorConfig, GraphSketch, MergeableState,
    MergedReservoir, ReservoirAction, Series, Snapshot, Weights, WindowConfig, WindowPolicy,
    WindowedReservoir,
};

/// Raw output of a MAEVE streaming run.
#[derive(Debug, Clone)]
pub struct MaeveEstimate {
    /// Order `|V|` inferred from the stream (max label + 1).
    pub nv: u64,
    /// `|E|` of the graph the estimate describes (window length under a
    /// sliding window, all-time stream length otherwise).
    pub ne: u64,
    /// Exact degrees.
    pub degrees: Vec<u32>,
    /// Estimated per-vertex triangle counts |T_G(v)|.
    pub triangles: Vec<f64>,
    /// Estimated per-vertex 3-path endpoint counts |P_G(v)|.
    pub paths: Vec<f64>,
}

impl MaeveEstimate {
    /// The five per-vertex features of Table 6, as columns.
    ///
    /// `[degree, clustering, avg-neighbor-degree, egonet-edges,
    /// egonet-leaving-edges]`
    pub fn features(&self) -> [Vec<f64>; 5] {
        let n = self.degrees.len();
        let mut f: [Vec<f64>; 5] = Default::default();
        for c in f.iter_mut() {
            c.reserve(n);
        }
        for v in 0..n {
            let d = self.degrees[v] as f64;
            let t = self.triangles[v];
            let p = self.paths[v];
            f[0].push(d);
            f[1].push(if d >= 2.0 { t / (d * (d - 1.0) / 2.0) } else { 0.0 });
            f[2].push(if d > 0.0 { 1.0 + p / d } else { 0.0 });
            f[3].push(d + t);
            f[4].push(p - 2.0 * t);
        }
        f
    }

    /// 20-dim descriptor (moment-major; rust mirror of the L2 kernel).
    pub fn descriptor(&self) -> [f64; 20] {
        maeve_layout(&self.features())
    }

    pub(crate) fn save(&self, out: &mut Enc) {
        out.u64(self.nv);
        out.u64(self.ne);
        out.usize(self.degrees.len());
        for d in &self.degrees {
            out.u32(*d);
        }
        for t in &self.triangles {
            out.f64(*t);
        }
        for p in &self.paths {
            out.f64(*p);
        }
    }

    pub(crate) fn load(d: &mut Dec<'_>) -> crate::Result<MaeveEstimate> {
        let nv = d.u64()?;
        let ne = d.u64()?;
        let n = d.seq_len(20)?;
        let mut degrees = Vec::with_capacity(n);
        for _ in 0..n {
            degrees.push(d.u32()?);
        }
        let mut triangles = Vec::with_capacity(n);
        for _ in 0..n {
            triangles.push(d.f64()?);
        }
        let mut paths = Vec::with_capacity(n);
        for _ in 0..n {
            paths.push(d.f64()?);
        }
        Ok(MaeveEstimate { nv, ne, degrees, triangles, paths })
    }
}

/// Streaming MAEVE estimator.
#[derive(Debug, Clone)]
pub struct MaeveEstimator {
    cfg: EstimatorConfig,
}

impl MaeveEstimator {
    /// Estimator with the given reservoir budget (paper's `b`), MAEVE's
    /// historical default seed and the reservoir backend — shorthand for
    /// [`MaeveEstimator::from_config`], which is the primary constructor.
    pub fn new(budget: usize) -> Self {
        MaeveEstimator::from_config(EstimatorConfig::new(budget).with_seed(0x3a3e))
    }

    /// Estimator from the shared [`EstimatorConfig`] (ISSUE 8) — budget,
    /// seed, window and [`Backend`] in one place.
    pub fn from_config(cfg: EstimatorConfig) -> Self {
        MaeveEstimator { cfg }
    }

    /// The estimator's configuration.
    pub fn config(&self) -> &EstimatorConfig {
        &self.cfg
    }

    /// Override the reservoir RNG / sketch hash seed.
    ///
    /// Note: delegating shim over [`EstimatorConfig::with_seed`]; prefer
    /// building an [`EstimatorConfig`] and [`MaeveEstimator::from_config`].
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.cfg = self.cfg.with_seed(seed);
        self
    }

    /// Set the window policy and snapshot cadence (ISSUE 5).  The default
    /// [`WindowPolicy::None`] reproduces the paper's full-history run
    /// bit-for-bit.
    ///
    /// Note: delegating shim over [`EstimatorConfig::with_window`]; prefer
    /// building an [`EstimatorConfig`] and [`MaeveEstimator::from_config`].
    pub fn with_window(mut self, window: WindowConfig) -> Self {
        self.cfg = self.cfg.with_window(window);
        self
    }

    /// Select the estimation backend (reservoir or sketch).
    ///
    /// Note: delegating shim over [`EstimatorConfig::with_backend`]; prefer
    /// building an [`EstimatorConfig`] and [`MaeveEstimator::from_config`].
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.cfg = self.cfg.with_backend(backend);
        self
    }

    /// Single-pass estimate.
    ///
    #[doc = include_str!("run_doc.md")]
    pub fn run(&self, stream: &mut impl EdgeStream) -> MaeveEstimate {
        self.try_run(stream).expect("maeve: edge stream failed")
    }

    /// **Primary entry point**: single-pass estimate, surfacing stream
    /// I/O failures as errors.  [`MaeveEstimator::run`] is the panicking
    /// convenience wrapper.
    pub fn try_run(&self, stream: &mut impl EdgeStream) -> crate::Result<MaeveEstimate> {
        Ok(self.try_run_series(stream)?.last)
    }

    /// Run and return the full descriptor time series (one snapshot per
    /// `stride` arrivals plus the final estimate).
    ///
    #[doc = include_str!("run_doc.md")]
    pub fn run_series(&self, stream: &mut impl EdgeStream) -> Series<MaeveEstimate> {
        self.try_run_series(stream).expect("maeve: edge stream failed")
    }

    /// **Primary entry point** for time series: like
    /// [`run_series`](MaeveEstimator::run_series), surfacing stream I/O
    /// failures as errors instead of panicking.
    pub fn try_run_series(
        &self,
        stream: &mut impl EdgeStream,
    ) -> crate::Result<Series<MaeveEstimate>> {
        self.cfg.validate()?;
        let mut state = MaeveState::from_config(&self.cfg);
        while let Some(e) = stream.next_edge() {
            state.push(e);
        }
        if let Some(e) = stream.take_error() {
            return Err(e.context("maeve stream truncated"));
        }
        let snapshots = state.take_snapshots();
        Ok(Series { snapshots, last: state.finish() })
    }
}

/// Apply one per-vertex credit, routing it through the active lifetime
/// model: straight `+=` for full history (bit-identical to the pre-window
/// path), lazily-decayed accumulation under [`WindowPolicy::Decay`]
/// (`rho < 1`), and logged into the expiry buckets under
/// [`WindowPolicy::Sliding`].  A free function (not a method) so the push
/// loops can hold disjoint borrows of the sample graph alongside it.
#[inline]
#[allow(clippy::too_many_arguments)]
fn credit_vertex(
    tri: &mut [f64],
    path: &mut [f64],
    log: &mut Option<VertexCreditLog>,
    rho: f64,
    last: &mut [u64],
    t: u64,
    v: usize,
    dtri: f64,
    dpath: f64,
) {
    if rho < 1.0 {
        let dt = t - last[v];
        if dt > 0 {
            let f = rho.powi(dt.min(i32::MAX as u64) as i32);
            tri[v] *= f;
            path[v] *= f;
            last[v] = t;
        }
    }
    tri[v] += dtri;
    path[v] += dpath;
    if let Some(log) = log {
        log.credit(v as u32, dtri, dpath);
    }
}

/// Incremental MAEVE estimator state (coordinator worker API).
#[derive(Debug)]
pub struct MaeveState {
    budget: usize,
    reservoir: WindowedReservoir,
    sample: SampleGraph,
    /// Exact degrees — windowed in sliding mode, all-time otherwise.
    degrees: Vec<u32>,
    /// Sliding mode's degree clock (last `w` stream edges).
    ring: Option<EdgeRing>,
    tri: Vec<f64>,
    path: Vec<f64>,
    common: Vec<u32>,
    /// Sliding mode: per-vertex credit expiry buckets.
    credit_log: Option<VertexCreditLog>,
    expired_credits: Vec<(u32, f64, f64)>,
    /// Decay mode: per-arrival retention `2^(-1/h)` (1.0 otherwise) and
    /// the per-vertex last-settled arrival for lazy decay.
    rho: f64,
    decay_last: Vec<u64>,
    expired: Vec<crate::graph::Edge>,
    window: WindowConfig,
    snapshots: Vec<Snapshot<MaeveEstimate>>,
    ne: u64,
    /// `Some` iff running on [`Backend::Sketch`] (ISSUE 8).
    sketch: Option<GraphSketch>,
}

impl MaeveState {
    /// Full-history state (the paper's setting).
    pub fn new(budget: usize, seed: u64) -> Self {
        Self::with_window(budget, seed, WindowConfig::default())
    }

    /// State under a window policy + snapshot cadence (ISSUE 5).
    pub fn with_window(budget: usize, seed: u64, window: WindowConfig) -> Self {
        Self::from_config(&EstimatorConfig::new(budget).with_seed(seed).with_window(window))
    }

    /// State from the shared [`EstimatorConfig`] (the primary
    /// constructor).  The config must have been validated (see
    /// [`EstimatorConfig::validate`]).
    pub fn from_config(cfg: &EstimatorConfig) -> Self {
        let b = cfg.budget.max(1);
        let (ring, credit_log) = match cfg.window.policy {
            WindowPolicy::Sliding { w } => {
                (Some(EdgeRing::new(w)), Some(VertexCreditLog::new(w)))
            }
            _ => (None, None),
        };
        let sketch = match cfg.backend {
            Backend::Sketch { width, depth } => Some(GraphSketch::new(width, depth, cfg.seed)),
            Backend::Reservoir => None,
        };
        MaeveState {
            budget: b,
            reservoir: WindowedReservoir::new(cfg.window.policy, b, Pcg64::seed_from_u64(cfg.seed)),
            sample: SampleGraph::new(),
            degrees: Vec::new(),
            ring,
            tri: Vec::new(),
            path: Vec::new(),
            common: Vec::new(),
            credit_log,
            expired_credits: Vec::new(),
            rho: cfg.window.policy.decay_factor(),
            decay_last: Vec::new(),
            expired: Vec::new(),
            window: cfg.window,
            snapshots: Vec::new(),
            ne: 0,
            sketch,
        }
    }

    /// Process one arriving edge.
    pub fn push(&mut self, e: crate::graph::Edge) {
        if let Some(sk) = &mut self.sketch {
            // sketch backend: O(1) bucket update + exact degrees; the
            // per-vertex credit machinery is read out at finalize time
            self.ne += 1;
            let (u, v) = (e.u, e.v);
            if self.degrees.len() <= v as usize {
                self.degrees.resize(v as usize + 1, 0);
            }
            self.degrees[u as usize] += 1;
            self.degrees[v as usize] += 1;
            sk.update(u, v);
            self.maybe_snapshot();
            return;
        }
        self.ne += 1;
        // sliding: retire per-vertex credits that fell out of the window
        if let Some(log) = &mut self.credit_log {
            self.expired_credits.clear();
            log.tick(&mut self.expired_credits);
            for &(v, dtri, dpath) in &self.expired_credits {
                self.tri[v as usize] -= dtri;
                self.path[v as usize] -= dpath;
            }
        }
        // phase 1: window clock + sample eviction
        let t_eff = self.reservoir.arrive(&mut self.expired);
        for old in self.expired.drain(..) {
            self.sample.remove(old.u, old.v);
        }

        let (u, v) = (e.u, e.v);
        let need = v as usize + 1;
        if self.degrees.len() < need {
            self.degrees.resize(need, 0);
            self.tri.resize(need, 0.0);
            self.path.resize(need, 0.0);
            if self.rho < 1.0 {
                self.decay_last.resize(need, self.ne);
            }
        }
        self.degrees[u as usize] += 1;
        self.degrees[v as usize] += 1;
        if let Some(ring) = &mut self.ring {
            if let Some(old) = ring.push(e) {
                self.degrees[old.u as usize] -= 1;
                self.degrees[old.v as usize] -= 1;
            }
        }

        if !self.sample.insert(u, v) {
            // duplicate stream edge: full-history mode offers it (paper
            // path, bit-compatible); windowed reservoirs skip it so the
            // sample and reservoir stay in lock-step (see gabe.rs).
            if !self.window.policy.is_windowed() {
                self.reservoir.offer(e);
            }
            self.maybe_snapshot();
            return;
        }
        let w = Weights::at(t_eff, self.budget);
        let (tri, path, log, last, rho, t) = (
            &mut self.tri,
            &mut self.path,
            &mut self.credit_log,
            &mut self.decay_last,
            self.rho,
            self.ne,
        );

        // triangles {u, v, w}: credit all three corners
        self.sample.common_neighbors_into(u, v, &mut self.common);
        for &wv in &self.common {
            credit_vertex(tri, path, log, rho, last, t, u as usize, w.w3, 0.0);
            credit_vertex(tri, path, log, rho, last, t, v as usize, w.w3, 0.0);
            credit_vertex(tri, path, log, rho, last, t, wv as usize, w.w3, 0.0);
        }
        // 3-paths w-u-v (endpoints w, v) and u-v-x (endpoints u, x)
        for wv in self.sample.neighbors(u) {
            if wv == v {
                continue;
            }
            credit_vertex(tri, path, log, rho, last, t, wv as usize, 0.0, w.w2);
            credit_vertex(tri, path, log, rho, last, t, v as usize, 0.0, w.w2);
        }
        for x in self.sample.neighbors(v) {
            if x == u {
                continue;
            }
            credit_vertex(tri, path, log, rho, last, t, x as usize, 0.0, w.w2);
            credit_vertex(tri, path, log, rho, last, t, u as usize, 0.0, w.w2);
        }

        match self.reservoir.offer(e) {
            ReservoirAction::Stored => {}
            ReservoirAction::Replaced(old) => {
                self.sample.remove(old.u, old.v);
            }
            ReservoirAction::Discarded => {
                self.sample.remove(u, v);
            }
        }
        self.maybe_snapshot();
    }

    /// Settle all lazy decay up to the current arrival (decay mode only).
    fn settle_decay(tri: &mut [f64], path: &mut [f64], last: &mut [u64], rho: f64, t: u64) {
        if rho >= 1.0 {
            return;
        }
        for v in 0..tri.len() {
            let dt = t - last[v];
            if dt > 0 {
                let f = rho.powi(dt.min(i32::MAX as u64) as i32);
                tri[v] *= f;
                path[v] *= f;
                last[v] = t;
            }
        }
    }

    /// The estimate as of the current arrival (snapshot path: clones).
    fn estimate_now(&self) -> MaeveEstimate {
        let (tri, path) = match &self.sketch {
            Some(sk) => sk.maeve_readout(&self.degrees),
            None => {
                let mut tri = self.tri.clone();
                let mut path = self.path.clone();
                let mut last = self.decay_last.clone();
                Self::settle_decay(&mut tri, &mut path, &mut last, self.rho, self.ne);
                (tri, path)
            }
        };
        MaeveEstimate {
            nv: self.degrees.len() as u64,
            ne: self.window.policy.described_len(self.ne),
            degrees: self.degrees.clone(),
            triangles: tri,
            paths: path,
        }
    }

    fn maybe_snapshot(&mut self) {
        if self.window.snapshot_due(self.ne) {
            let estimate = self.estimate_now();
            self.snapshots.push(Snapshot { t: self.ne, estimate });
        }
    }

    /// Drain the snapshots recorded so far (coordinator barrier merge).
    pub fn take_snapshots(&mut self) -> Vec<Snapshot<MaeveEstimate>> {
        std::mem::take(&mut self.snapshots)
    }

    /// Finalize into per-vertex estimates.
    pub fn finish(mut self) -> MaeveEstimate {
        if let Some(sk) = &self.sketch {
            let (tri, path) = sk.maeve_readout(&self.degrees);
            return MaeveEstimate {
                nv: self.degrees.len() as u64,
                ne: self.window.policy.described_len(self.ne),
                degrees: self.degrees,
                triangles: tri,
                paths: path,
            };
        }
        Self::settle_decay(
            &mut self.tri,
            &mut self.path,
            &mut self.decay_last,
            self.rho,
            self.ne,
        );
        MaeveEstimate {
            nv: self.degrees.len() as u64,
            ne: self.window.policy.described_len(self.ne),
            degrees: self.degrees,
            triangles: self.tri,
            paths: self.path,
        }
    }

    /// Serialize the complete estimator state (ISSUE 7).  Scratch buffers
    /// (`common`, `expired_credits`, `expired`) are empty between arrivals
    /// and restore as defaults; lazy decay is *not* settled — the
    /// per-vertex `decay_last` clocks are captured raw so resumed runs
    /// keep the original multiply schedule bit-for-bit.
    pub(crate) fn save(&self, out: &mut Enc) {
        out.usize(self.budget);
        self.window.save(out);
        self.reservoir.save(out);
        self.sample.save(out);
        out.usize(self.degrees.len());
        for deg in &self.degrees {
            out.u32(*deg);
        }
        for t in &self.tri {
            out.f64(*t);
        }
        for p in &self.path {
            out.f64(*p);
        }
        match &self.ring {
            None => out.u8(0),
            Some(r) => {
                out.u8(1);
                r.save(out);
            }
        }
        match &self.credit_log {
            None => out.u8(0),
            Some(log) => {
                out.u8(1);
                log.save(out);
            }
        }
        out.f64(self.rho);
        out.usize(self.decay_last.len());
        for l in &self.decay_last {
            out.u64(*l);
        }
        out.usize(self.snapshots.len());
        for s in &self.snapshots {
            out.u64(s.t);
            s.estimate.save(out);
        }
        out.u64(self.ne);
        match &self.sketch {
            None => out.u8(0),
            Some(sk) => {
                out.u8(1);
                sk.save(out);
            }
        }
    }

    /// Rebuild from [`MaeveState::save`] bytes.
    pub(crate) fn load(d: &mut Dec<'_>) -> crate::Result<MaeveState> {
        let budget = d.usize()?;
        crate::ensure!(budget > 0, "maeve checkpoint: zero budget");
        let window = WindowConfig::load(d)?;
        let reservoir = WindowedReservoir::load(d)?;
        let sample = SampleGraph::load(d)?;
        let n = d.seq_len(20)?;
        let mut degrees = Vec::with_capacity(n);
        for _ in 0..n {
            degrees.push(d.u32()?);
        }
        let mut tri = Vec::with_capacity(n);
        for _ in 0..n {
            tri.push(d.f64()?);
        }
        let mut path = Vec::with_capacity(n);
        for _ in 0..n {
            path.push(d.f64()?);
        }
        let ring = match d.u8()? {
            0 => None,
            1 => Some(EdgeRing::load(d)?),
            tag => return Err(crate::anyhow!("maeve checkpoint: unknown ring tag {tag}")),
        };
        let credit_log = match d.u8()? {
            0 => None,
            1 => Some(VertexCreditLog::load(d)?),
            tag => return Err(crate::anyhow!("maeve checkpoint: unknown log tag {tag}")),
        };
        let rho = d.f64()?;
        let n_last = d.seq_len(8)?;
        let mut decay_last = Vec::with_capacity(n_last);
        for _ in 0..n_last {
            decay_last.push(d.u64()?);
        }
        let n_snaps = d.seq_len(8)?;
        let mut snapshots = Vec::with_capacity(n_snaps);
        for _ in 0..n_snaps {
            let t = d.u64()?;
            let estimate = MaeveEstimate::load(d)?;
            snapshots.push(Snapshot { t, estimate });
        }
        let ne = d.u64()?;
        let sketch = match d.u8()? {
            0 => None,
            1 => Some(GraphSketch::load(d)?),
            tag => return Err(crate::anyhow!("maeve checkpoint: unknown sketch tag {tag}")),
        };
        Ok(MaeveState {
            budget,
            reservoir,
            sample,
            degrees,
            ring,
            tri,
            path,
            common: Vec::new(),
            credit_log,
            expired_credits: Vec::new(),
            rho,
            decay_last,
            expired: Vec::new(),
            window,
            snapshots,
            ne,
            sketch,
        })
    }

    /// Entrywise merge of a sketch-backend shard into this one
    /// (coordinator shard mode); see `GabeState::merge_from`.
    pub(crate) fn merge_from(&mut self, other: &MaeveState) -> crate::Result<()> {
        let Some(sk) = &mut self.sketch else {
            return Err(crate::anyhow!("maeve merge: reservoir states are not mergeable"));
        };
        let Some(osk) = &other.sketch else {
            return Err(crate::anyhow!("maeve merge: backend mismatch"));
        };
        sk.merge(osk)?;
        if self.degrees.len() < other.degrees.len() {
            self.degrees.resize(other.degrees.len(), 0);
        }
        for (i, d) in other.degrees.iter().enumerate() {
            self.degrees[i] += d;
        }
        self.ne += other.ne;
        Ok(())
    }

    /// Distributed reservoir merge (ISSUE 10, DESIGN.md §13): combine K
    /// independent full-history shard states into one estimate by lifting
    /// each shard reservoir into a weighted [`MergedReservoir`], merging
    /// under `merge_seed`, replaying the merged uniform sample through a
    /// fresh exact-regime state (budget ≥ sample, every weight 1) and
    /// rescaling the raw per-vertex counts by the merged sample's own
    /// inclusion probabilities: triangles (3 edges) by `1/p(3)`, 3-path
    /// endpoints (2 edges) by `1/p(2)`.  Degrees, `nv` and `ne` are exact
    /// shard sums.
    pub(crate) fn merge_reservoir_shards(
        states: &[MaeveState],
        merge_seed: u64,
    ) -> crate::Result<MaeveEstimate> {
        crate::ensure!(!states.is_empty(), "maeve shard merge: no shard states");
        let mut merged: Option<MergedReservoir> = None;
        let mut degrees: Vec<u32> = Vec::new();
        let mut ne = 0u64;
        for s in states {
            crate::ensure!(
                s.sketch.is_none(),
                "maeve shard merge: sketch states merge entrywise, not by subsampling"
            );
            crate::ensure!(
                matches!(s.window.policy, WindowPolicy::None),
                "maeve shard merge: windowed states cannot be merged"
            );
            let WindowedReservoir::Full(r) = &s.reservoir else {
                return Err(crate::anyhow!(
                    "maeve shard merge: windowed reservoir in an unwindowed state"
                ));
            };
            let lifted = MergedReservoir::from_reservoir(r, merge_seed);
            merged = Some(match merged {
                None => lifted,
                Some(mut m) => {
                    m.merge_state(&lifted)?;
                    m
                }
            });
            if degrees.len() < s.degrees.len() {
                degrees.resize(s.degrees.len(), 0);
            }
            for (i, d) in s.degrees.iter().enumerate() {
                degrees[i] += d;
            }
            ne += s.ne;
        }
        let (sample, t_total) = merged.expect("states is non-empty").into_sample();
        let mut replay = MaeveState::from_config(&EstimatorConfig::new(sample.len().max(1)));
        for &e in &sample {
            replay.push(e);
        }
        let p3 = sample_inclusion_probability(3, t_total, sample.len());
        let p2 = sample_inclusion_probability(2, t_total, sample.len());
        let n = degrees.len();
        let mut triangles = replay.tri;
        let mut paths = replay.path;
        triangles.resize(n, 0.0);
        paths.resize(n, 0.0);
        for v in 0..n {
            if triangles[v] != 0.0 {
                triangles[v] /= p3;
            }
            if paths[v] != 0.0 {
                paths[v] /= p2;
            }
        }
        Ok(MaeveEstimate { nv: n as u64, ne, degrees, triangles, paths })
    }

    /// Approximate resident bytes of the estimator state — the memory
    /// axis of the `repro sketch` accuracy-vs-memory comparison.
    pub fn resident_bytes(&self) -> usize {
        let vertices = self.degrees.len() * 4 + self.tri.len() * 8 + self.path.len() * 8;
        match &self.sketch {
            Some(sk) => sk.bytes() + self.degrees.len() * 4,
            None => {
                self.budget * 8
                    + self.sample.arena_len() * 4
                    + self.sample.intern_capacity() * 8
                    + vertices
            }
        }
    }
}

/// [`GraphDescriptor`] adapter.
#[derive(Debug, Clone)]
pub struct Maeve {
    /// Reservoir budget to resolve against each graph's `|E|`.
    pub budget: Budget,
}

impl GraphDescriptor for Maeve {
    fn name(&self) -> String {
        match self.budget {
            Budget::Fraction(f) => format!("MAEVE@{f}"),
            Budget::Edges(b) => format!("MAEVE@b={b}"),
            Budget::Exact => "MAEVE@exact".into(),
        }
    }

    fn dim(&self) -> usize {
        20
    }

    fn compute(&self, g: &Graph, seed: u64) -> Vec<f64> {
        let mut stream = super::stream_of(g, seed);
        let b = super::resolve_budget(self.budget, &stream)
            .expect("VecStream always has a len hint");
        let est = MaeveEstimator::new(b).with_seed(seed ^ 0x3ae0).run(&mut stream);
        est.descriptor().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::csr::Csr;
    use crate::graph::stream::VecStream;

    /// Exact per-vertex triangle / 3-path counts on the full graph.
    fn exact_tp(g: &Graph) -> (Vec<f64>, Vec<f64>) {
        let c = Csr::from_graph(g);
        let mut tri = vec![0.0; g.n];
        let mut path = vec![0.0; g.n];
        for u in 0..g.n as u32 {
            for &v in c.neighbors(u) {
                if v <= u {
                    continue;
                }
                // triangles on edge (u, v)
                for &w in c.neighbors(u) {
                    if w > v && c.has_edge(w, v) {
                        tri[u as usize] += 1.0;
                        tri[v as usize] += 1.0;
                        tri[w as usize] += 1.0;
                    }
                }
            }
            // 3-paths with endpoint u: u-m-w
            for &m in c.neighbors(u) {
                for &w in c.neighbors(m) {
                    if w != u {
                        path[u as usize] += 0.5; // counted from both ends below
                        path[w as usize] += 0.5;
                    }
                }
            }
        }
        (tri, path)
    }

    #[test]
    fn exact_mode_matches_direct_computation() {
        let mut rng = Pcg64::seed_from_u64(11);
        let g = gen::er_graph(25, 60, &mut rng);
        let (tri, path) = exact_tp(&g);
        let mut s = VecStream::shuffled(g.edges.clone(), 1);
        let est = MaeveEstimator::new(g.m()).run(&mut s);
        for v in 0..g.n {
            assert!((est.triangles[v] - tri[v]).abs() < 1e-6, "tri[{v}]");
            assert!((est.paths[v] - path[v]).abs() < 1e-6, "path[{v}]");
        }
    }

    #[test]
    fn theorem3_feature_identities_on_exact_counts() {
        // On exact counts, egonet edges = d + T and avg neighbor degree =
        // 1 + P/d must match direct inspection.
        let g = Graph::from_pairs([(0, 1), (1, 2), (0, 2), (0, 3), (3, 4)]);
        let mut s = VecStream::new(g.edges.clone());
        let est = MaeveEstimator::new(100).run(&mut s);
        let f = est.features();
        // vertex 0: N={1,2,3}; egonet edges: (0,1),(0,2),(0,3),(1,2) = 4
        assert_eq!(f[3][0], 4.0);
        // vertex 0 avg neighbor degree: (2+2+2)/3 = 2
        assert!((f[2][0] - 2.0).abs() < 1e-9);
        // edges leaving egonet of 0: (3,4) only = 1
        assert!((f[4][0] - 1.0).abs() < 1e-9);
        // clustering of 0: T=1, C(3,2)=3
        assert!((f[1][0] - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn budgeted_vertex_counts_unbiased() {
        let mut rng = Pcg64::seed_from_u64(12);
        let g = gen::powerlaw_cluster_graph(50, 4, 0.6, &mut rng);
        let (tri, _) = exact_tp(&g);
        let runs = 400;
        let mut mean = vec![0.0; g.n];
        for r in 0..runs {
            let mut s = VecStream::shuffled(g.edges.clone(), r);
            let est = MaeveEstimator::new(g.m() / 2).with_seed(r ^ 1).run(&mut s);
            for v in 0..g.n {
                mean[v] += est.triangles[v] / runs as f64;
            }
        }
        let total_true: f64 = tri.iter().sum();
        let total_mean: f64 = mean.iter().sum();
        assert!(
            (total_mean - total_true).abs() / total_true < 0.06,
            "{total_mean} vs {total_true}"
        );
    }

    /// ISSUE 5 differential: `WindowPolicy::None` and `Sliding{w ≥ |E|}`
    /// reproduce the full-history MAEVE run bit-for-bit.
    #[test]
    fn window_none_and_huge_sliding_are_bit_identical_to_full_history() {
        let mut rng = Pcg64::seed_from_u64(41);
        let g = gen::powerlaw_cluster_graph(80, 3, 0.5, &mut rng);
        let b = g.m() / 3;
        let mut s = VecStream::shuffled(g.edges.clone(), 5);
        let base = MaeveEstimator::new(b).with_seed(13).run(&mut s);
        for policy in [WindowPolicy::None, WindowPolicy::Sliding { w: g.m() + 1 }] {
            let mut s = VecStream::shuffled(g.edges.clone(), 5);
            let est = MaeveEstimator::new(b)
                .with_seed(13)
                .with_window(WindowConfig::new(policy))
                .run(&mut s);
            assert_eq!(est.triangles, base.triangles, "{policy:?} diverged");
            assert_eq!(est.paths, base.paths);
            assert_eq!(est.degrees, base.degrees);
            assert_eq!((est.nv, est.ne), (base.nv, base.ne));
        }
    }

    /// Windowed MAEVE: degrees track the last `w` edges exactly, and the
    /// per-vertex credits shed their expired mass (total triangle credit
    /// over a drifting stream stays bounded instead of growing).
    #[test]
    fn sliding_maeve_windows_degrees_and_credits() {
        let mut rng = Pcg64::seed_from_u64(42);
        let g = gen::powerlaw_cluster_graph(60, 4, 0.6, &mut rng);
        let w = g.m() / 4;
        let window = WindowConfig::new(WindowPolicy::Sliding { w }).with_stride(w / 2);
        let mut s = VecStream::shuffled(g.edges.clone(), 3);
        // exact-within-window regime: budget covers the whole window
        let series = MaeveEstimator::new(g.m()).with_window(window).run_series(&mut s);
        let stream = VecStream::shuffled(g.edges.clone(), 3);
        let tail = &stream.edges()[g.m() - w..];
        let mut want = vec![0u32; series.last.degrees.len()];
        for e in tail {
            want[e.u as usize] += 1;
            want[e.v as usize] += 1;
        }
        assert_eq!(series.last.degrees, want);
        assert_eq!(series.last.ne, w as u64);
        // full-history credit keeps growing; windowed credit is bounded by
        // the window's own (smaller) triangle mass
        let mut s = VecStream::shuffled(g.edges.clone(), 3);
        let full = MaeveEstimator::new(g.m()).run(&mut s);
        let windowed_total: f64 = series.last.triangles.iter().sum();
        let full_total: f64 = full.triangles.iter().sum();
        assert!(
            windowed_total < full_total,
            "windowed {windowed_total} !< full {full_total}"
        );
        for snap in &series.snapshots {
            assert!(snap.estimate.triangles.iter().all(|x| x.is_finite()));
        }
    }

    /// ISSUE 10: with budget ≥ |E| per shard, the merged sample is the
    /// whole edge set, every inclusion probability is 1 and the shard
    /// merge reproduces the exact per-vertex counts.
    #[test]
    fn shard_merge_with_full_budget_is_exact() {
        let mut rng = Pcg64::seed_from_u64(22);
        let g = gen::powerlaw_cluster_graph(50, 3, 0.5, &mut rng);
        let (tri, path) = exact_tp(&g);
        for k in [1usize, 2, 4] {
            let cfg = EstimatorConfig::new(g.m() + 1);
            let mut shards: Vec<MaeveState> =
                (0..k).map(|_| MaeveState::from_config(&cfg)).collect();
            for (i, &e) in g.edges.iter().enumerate() {
                shards[i % k].push(e);
            }
            let est = MaeveState::merge_reservoir_shards(&shards, 0xfeed).unwrap();
            for v in 0..g.n {
                assert!((est.triangles[v] - tri[v]).abs() < 1e-6, "k={k} tri[{v}]");
                assert!((est.paths[v] - path[v]).abs() < 1e-6, "k={k} path[{v}]");
            }
            assert_eq!(est.degrees, g.degrees());
            assert_eq!(est.ne as usize, g.m());
        }
    }

    #[test]
    fn shard_merge_rejects_sketch_and_windowed_states() {
        let sketchy = MaeveState::from_config(
            &EstimatorConfig::new(8).with_backend(Backend::sketch_default()),
        );
        let err = MaeveState::merge_reservoir_shards(&[sketchy], 1).unwrap_err();
        assert!(err.to_string().contains("entrywise"), "{err}");
        let windowed = MaeveState::from_config(
            &EstimatorConfig::new(8)
                .with_window(WindowConfig::new(WindowPolicy::Sliding { w: 4 })),
        );
        let err = MaeveState::merge_reservoir_shards(&[windowed], 1).unwrap_err();
        assert!(err.to_string().contains("windowed"), "{err}");
    }

    #[test]
    fn descriptor_finite_on_star_and_empty_vertices() {
        // star: center degree n-1, leaves degree 1, no triangles
        let g = Graph::from_pairs((1..20).map(|i| (0u32, i)));
        let mut s = VecStream::new(g.edges.clone());
        let est = MaeveEstimator::new(1000).run(&mut s);
        let d = est.descriptor();
        assert!(d.iter().all(|x| x.is_finite()));
        let f = est.features();
        assert_eq!(f[1][0], 0.0); // clustering of center
    }
}
