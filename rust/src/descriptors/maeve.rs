//! MAEVE — Moments of Attributes Estimated on Vertices Efficiently (§4.2).
//!
//! One pass.  Per-vertex triangle counts `|T_G(v)|` and 3-path-endpoint
//! counts `|P_G(v)|` are estimated with the reservoir scheme; degrees are
//! exact.  Theorem 3 turns (d, T, P) into the five NetSimile-style
//! features, and four moments (mean, std, skew, excess kurtosis) aggregate
//! each feature over the vertices — a 20-dim descriptor.

use crate::util::rng::Pcg64;

use super::{Budget, GraphDescriptor};
use crate::graph::adjacency::SampleGraph;
use crate::graph::stream::EdgeStream;
use crate::graph::Graph;
use crate::linalg::moments::maeve_layout;
use crate::sampling::{Reservoir, ReservoirAction, Weights};

/// Raw output of a MAEVE streaming run.
#[derive(Debug, Clone)]
pub struct MaeveEstimate {
    pub nv: u64,
    pub ne: u64,
    /// Exact degrees.
    pub degrees: Vec<u32>,
    /// Estimated per-vertex triangle counts |T_G(v)|.
    pub triangles: Vec<f64>,
    /// Estimated per-vertex 3-path endpoint counts |P_G(v)|.
    pub paths: Vec<f64>,
}

impl MaeveEstimate {
    /// The five per-vertex features of Table 6, as columns.
    ///
    /// `[degree, clustering, avg-neighbor-degree, egonet-edges,
    /// egonet-leaving-edges]`
    pub fn features(&self) -> [Vec<f64>; 5] {
        let n = self.degrees.len();
        let mut f: [Vec<f64>; 5] = Default::default();
        for c in f.iter_mut() {
            c.reserve(n);
        }
        for v in 0..n {
            let d = self.degrees[v] as f64;
            let t = self.triangles[v];
            let p = self.paths[v];
            f[0].push(d);
            f[1].push(if d >= 2.0 { t / (d * (d - 1.0) / 2.0) } else { 0.0 });
            f[2].push(if d > 0.0 { 1.0 + p / d } else { 0.0 });
            f[3].push(d + t);
            f[4].push(p - 2.0 * t);
        }
        f
    }

    /// 20-dim descriptor (moment-major; rust mirror of the L2 kernel).
    pub fn descriptor(&self) -> [f64; 20] {
        maeve_layout(&self.features())
    }
}

/// Streaming MAEVE estimator.
#[derive(Debug, Clone)]
pub struct MaeveEstimator {
    budget: usize,
    seed: u64,
}

impl MaeveEstimator {
    pub fn new(budget: usize) -> Self {
        MaeveEstimator { budget, seed: 0x3a3e }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Single-pass estimate.
    ///
    /// # Panics
    ///
    /// Panics when the stream records an I/O failure (`EdgeStream::
    /// take_error`); use [`MaeveEstimator::try_run`] to handle stream
    /// failures as errors.
    pub fn run(&self, stream: &mut impl EdgeStream) -> MaeveEstimate {
        self.try_run(stream).expect("maeve: edge stream failed")
    }

    /// Like [`MaeveEstimator::run`], surfacing stream I/O failures as
    /// errors instead of panicking.
    pub fn try_run(&self, stream: &mut impl EdgeStream) -> crate::Result<MaeveEstimate> {
        let mut state = MaeveState::new(self.budget, self.seed);
        while let Some(e) = stream.next_edge() {
            state.push(e);
        }
        if let Some(e) = stream.take_error() {
            return Err(e.context("maeve stream truncated"));
        }
        Ok(state.finish())
    }
}

/// Incremental MAEVE estimator state (coordinator worker API).
#[derive(Debug)]
pub struct MaeveState {
    budget: usize,
    reservoir: Reservoir,
    sample: SampleGraph,
    degrees: Vec<u32>,
    tri: Vec<f64>,
    path: Vec<f64>,
    common: Vec<u32>,
    ne: u64,
}

impl MaeveState {
    pub fn new(budget: usize, seed: u64) -> Self {
        let b = budget.max(1);
        MaeveState {
            budget: b,
            reservoir: Reservoir::new(b, Pcg64::seed_from_u64(seed)),
            sample: SampleGraph::new(),
            degrees: Vec::new(),
            tri: Vec::new(),
            path: Vec::new(),
            common: Vec::new(),
            ne: 0,
        }
    }

    pub fn push(&mut self, e: crate::graph::Edge) {
        self.ne += 1;
        let (u, v) = (e.u, e.v);
        let need = v as usize + 1;
        if self.degrees.len() < need {
            self.degrees.resize(need, 0);
            self.tri.resize(need, 0.0);
            self.path.resize(need, 0.0);
        }
        self.degrees[u as usize] += 1;
        self.degrees[v as usize] += 1;

        let t = self.reservoir.t() + 1;
        if !self.sample.insert(u, v) {
            self.reservoir.offer(e);
            return;
        }
        let w = Weights::at(t, self.budget);

        // triangles {u, v, w}: credit all three corners
        self.sample.common_neighbors_into(u, v, &mut self.common);
        for &wv in &self.common {
            self.tri[u as usize] += w.w3;
            self.tri[v as usize] += w.w3;
            self.tri[wv as usize] += w.w3;
        }
        // 3-paths w-u-v (endpoints w, v) and u-v-x (endpoints u, x)
        for wv in self.sample.neighbors(u) {
            if wv == v {
                continue;
            }
            self.path[wv as usize] += w.w2;
            self.path[v as usize] += w.w2;
        }
        for x in self.sample.neighbors(v) {
            if x == u {
                continue;
            }
            self.path[x as usize] += w.w2;
            self.path[u as usize] += w.w2;
        }

        match self.reservoir.offer(e) {
            ReservoirAction::Stored => {}
            ReservoirAction::Replaced(old) => {
                self.sample.remove(old.u, old.v);
            }
            ReservoirAction::Discarded => {
                self.sample.remove(u, v);
            }
        }
    }

    pub fn finish(self) -> MaeveEstimate {
        MaeveEstimate {
            nv: self.degrees.len() as u64,
            ne: self.ne,
            degrees: self.degrees,
            triangles: self.tri,
            paths: self.path,
        }
    }
}

/// [`GraphDescriptor`] adapter.
#[derive(Debug, Clone)]
pub struct Maeve {
    pub budget: Budget,
}

impl GraphDescriptor for Maeve {
    fn name(&self) -> String {
        match self.budget {
            Budget::Fraction(f) => format!("MAEVE@{f}"),
            Budget::Edges(b) => format!("MAEVE@b={b}"),
            Budget::Exact => "MAEVE@exact".into(),
        }
    }

    fn dim(&self) -> usize {
        20
    }

    fn compute(&self, g: &Graph, seed: u64) -> Vec<f64> {
        let mut stream = super::stream_of(g, seed);
        let b = super::resolve_budget(self.budget, &stream);
        let est = MaeveEstimator::new(b).with_seed(seed ^ 0x3ae0).run(&mut stream);
        est.descriptor().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::csr::Csr;
    use crate::graph::stream::VecStream;

    /// Exact per-vertex triangle / 3-path counts on the full graph.
    fn exact_tp(g: &Graph) -> (Vec<f64>, Vec<f64>) {
        let c = Csr::from_graph(g);
        let mut tri = vec![0.0; g.n];
        let mut path = vec![0.0; g.n];
        for u in 0..g.n as u32 {
            for &v in c.neighbors(u) {
                if v <= u {
                    continue;
                }
                // triangles on edge (u, v)
                for &w in c.neighbors(u) {
                    if w > v && c.has_edge(w, v) {
                        tri[u as usize] += 1.0;
                        tri[v as usize] += 1.0;
                        tri[w as usize] += 1.0;
                    }
                }
            }
            // 3-paths with endpoint u: u-m-w
            for &m in c.neighbors(u) {
                for &w in c.neighbors(m) {
                    if w != u {
                        path[u as usize] += 0.5; // counted from both ends below
                        path[w as usize] += 0.5;
                    }
                }
            }
        }
        (tri, path)
    }

    #[test]
    fn exact_mode_matches_direct_computation() {
        let mut rng = Pcg64::seed_from_u64(11);
        let g = gen::er_graph(25, 60, &mut rng);
        let (tri, path) = exact_tp(&g);
        let mut s = VecStream::shuffled(g.edges.clone(), 1);
        let est = MaeveEstimator::new(g.m()).run(&mut s);
        for v in 0..g.n {
            assert!((est.triangles[v] - tri[v]).abs() < 1e-6, "tri[{v}]");
            assert!((est.paths[v] - path[v]).abs() < 1e-6, "path[{v}]");
        }
    }

    #[test]
    fn theorem3_feature_identities_on_exact_counts() {
        // On exact counts, egonet edges = d + T and avg neighbor degree =
        // 1 + P/d must match direct inspection.
        let g = Graph::from_pairs([(0, 1), (1, 2), (0, 2), (0, 3), (3, 4)]);
        let mut s = VecStream::new(g.edges.clone());
        let est = MaeveEstimator::new(100).run(&mut s);
        let f = est.features();
        // vertex 0: N={1,2,3}; egonet edges: (0,1),(0,2),(0,3),(1,2) = 4
        assert_eq!(f[3][0], 4.0);
        // vertex 0 avg neighbor degree: (2+2+2)/3 = 2
        assert!((f[2][0] - 2.0).abs() < 1e-9);
        // edges leaving egonet of 0: (3,4) only = 1
        assert!((f[4][0] - 1.0).abs() < 1e-9);
        // clustering of 0: T=1, C(3,2)=3
        assert!((f[1][0] - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn budgeted_vertex_counts_unbiased() {
        let mut rng = Pcg64::seed_from_u64(12);
        let g = gen::powerlaw_cluster_graph(50, 4, 0.6, &mut rng);
        let (tri, _) = exact_tp(&g);
        let runs = 400;
        let mut mean = vec![0.0; g.n];
        for r in 0..runs {
            let mut s = VecStream::shuffled(g.edges.clone(), r);
            let est = MaeveEstimator::new(g.m() / 2).with_seed(r ^ 1).run(&mut s);
            for v in 0..g.n {
                mean[v] += est.triangles[v] / runs as f64;
            }
        }
        let total_true: f64 = tri.iter().sum();
        let total_mean: f64 = mean.iter().sum();
        assert!(
            (total_mean - total_true).abs() / total_true < 0.06,
            "{total_mean} vs {total_true}"
        );
    }

    #[test]
    fn descriptor_finite_on_star_and_empty_vertices() {
        // star: center degree n-1, leaves degree 1, no triangles
        let g = Graph::from_pairs((1..20).map(|i| (0u32, i)));
        let mut s = VecStream::new(g.edges.clone());
        let est = MaeveEstimator::new(1000).run(&mut s);
        let d = est.descriptor();
        assert!(d.iter().all(|x| x.is_finite()));
        let f = est.features();
        assert_eq!(f[1][0], 0.0); // clustering of center
    }
}
