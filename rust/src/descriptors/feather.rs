//! FEATHER (Rozemberczki & Sarkar, CIKM'20) — characteristic-function
//! comparator (§5.3).
//!
//! FEATHER-G pools node-level characteristic functions of node features
//! under r-step normalized-adjacency propagation:
//!
//! ```text
//! φ_u^{(r)}(θ) = Σ_v (D⁻¹A)^r_{uv} · e^{i θ x_v}
//! ```
//!
//! evaluated on an evenly spaced θ grid, real and imaginary parts pooled by
//! mean over vertices.  Features: log-degree and clustering coefficient
//! (karateclub defaults); orders r ∈ {1, 2}; 16 θ points in (0, 2.5] —
//! a 128-dim descriptor.

use super::GraphDescriptor;
use crate::graph::csr::Csr;
use crate::graph::Graph;

/// θ grid resolution.
pub const N_THETA: usize = 16;
/// Propagation orders used.
pub const ORDERS_R: usize = 2;
/// Node features used (log-degree, clustering coefficient).
pub const N_FEATURES: usize = 2;
/// Total descriptor dimensionality.
pub const FEATHER_DIM: usize = N_FEATURES * ORDERS_R * N_THETA * 2;

/// FEATHER-G with mean pooling.
#[derive(Debug, Clone, Default)]
pub struct Feather;

impl Feather {
    /// Per-node features: [log(1+d_v), clustering(v)].
    fn node_features(csr: &Csr) -> Vec<[f64; N_FEATURES]> {
        let n = csr.n;
        let mut tri = vec![0.0f64; n];
        for u in 0..n as u32 {
            for &v in csr.neighbors(u) {
                if v <= u {
                    continue;
                }
                let (a, b) = (csr.neighbors(u), csr.neighbors(v));
                let (mut i, mut j) = (0, 0);
                while i < a.len() && j < b.len() {
                    match a[i].cmp(&b[j]) {
                        std::cmp::Ordering::Less => i += 1,
                        std::cmp::Ordering::Greater => j += 1,
                        std::cmp::Ordering::Equal => {
                            if a[i] > v {
                                tri[u as usize] += 1.0;
                                tri[v as usize] += 1.0;
                                tri[a[i] as usize] += 1.0;
                            }
                            i += 1;
                            j += 1;
                        }
                    }
                }
            }
        }
        (0..n)
            .map(|v| {
                let d = csr.degree(v as u32) as f64;
                let c = if d >= 2.0 { tri[v] / (d * (d - 1.0) / 2.0) } else { 0.0 };
                [(1.0 + d).ln(), c]
            })
            .collect()
    }

    /// Pooled characteristic-function descriptor of `g`.
    pub fn descriptor(&self, g: &Graph) -> Vec<f64> {
        let csr = Csr::from_graph(g);
        let n = csr.n.max(1);
        let feats = Self::node_features(&csr);
        let thetas: Vec<f64> =
            (1..=N_THETA).map(|k| 2.5 * k as f64 / N_THETA as f64).collect();

        let mut out = Vec::with_capacity(FEATHER_DIM);
        for f in 0..N_FEATURES {
            // wave[v] = (re, im) of e^{iθ x_v} for each θ; propagate r times.
            for &theta in &thetas {
                let mut re: Vec<f64> =
                    feats.iter().map(|x| (theta * x[f]).cos()).collect();
                let mut im: Vec<f64> =
                    feats.iter().map(|x| (theta * x[f]).sin()).collect();
                for _r in 0..ORDERS_R {
                    // one step of D⁻¹A propagation
                    let mut nre = vec![0.0; n];
                    let mut nim = vec![0.0; n];
                    for u in 0..n {
                        let d = csr.degree(u as u32);
                        if d == 0 {
                            continue;
                        }
                        let inv = 1.0 / d as f64;
                        let (mut ar, mut ai) = (0.0, 0.0);
                        for &v in csr.neighbors(u as u32) {
                            ar += re[v as usize];
                            ai += im[v as usize];
                        }
                        nre[u] = ar * inv;
                        nim[u] = ai * inv;
                    }
                    re = nre;
                    im = nim;
                    // mean pooling of this order
                    out.push(re.iter().sum::<f64>() / n as f64);
                    out.push(im.iter().sum::<f64>() / n as f64);
                }
            }
        }
        debug_assert_eq!(out.len(), FEATHER_DIM);
        out
    }
}

impl GraphDescriptor for Feather {
    fn name(&self) -> String {
        "FEATHER".into()
    }

    fn dim(&self) -> usize {
        FEATHER_DIM
    }

    fn compute(&self, g: &Graph, _seed: u64) -> Vec<f64> {
        self.descriptor(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::rng::Pcg64;

    #[test]
    fn dimension_is_fixed() {
        let g = Graph::from_pairs([(0, 1), (1, 2)]);
        assert_eq!(Feather.descriptor(&g).len(), FEATHER_DIM);
    }

    #[test]
    fn values_bounded_by_unit_circle() {
        let mut rng = Pcg64::seed_from_u64(41);
        let g = gen::ba_graph(200, 3, &mut rng);
        let d = Feather.descriptor(&g);
        assert!(d.iter().all(|x| x.abs() <= 1.0 + 1e-9));
    }

    #[test]
    fn isomorphism_invariant() {
        let g1 = Graph::from_pairs([(0, 1), (1, 2), (2, 3), (0, 2)]);
        let g2 = Graph::from_pairs([(3, 2), (2, 1), (1, 0), (3, 1)]); // relabel
        let a = Feather.descriptor(&g1);
        let b = Feather.descriptor(&g2);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn distinguishes_star_from_cycle() {
        let star = Graph::from_pairs((1..8u32).map(|i| (0, i)));
        let cycle =
            Graph::from_pairs((0..8u32).map(|i| (i, (i + 1) % 8)));
        let a = Feather.descriptor(&star);
        let b = Feather.descriptor(&cycle);
        let diff: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 0.5, "diff = {diff}");
    }
}
