//! NetLSD (Tsitsulin et al., KDD'18) — the exact spectral baseline (§5.3).
//!
//! Full eigenspectrum of the normalized Laplacian for graphs up to
//! `dense_cutoff`; beyond that, the paper's own §6.3 approximation: `k`
//! eigenvalues from each end via Lanczos, middle linearly interpolated.

use crate::util::rng::Pcg64;

use super::psi::{psi_from_eigenvalues, N_J, N_VARIANTS};
use super::GraphDescriptor;
use crate::graph::csr::Csr;
use crate::graph::Graph;
use crate::linalg::lanczos::{interpolate_spectrum, lanczos_extreme_eigenvalues};
use crate::linalg::symmetric_eigenvalues;

/// NetLSD embedding engine.
#[derive(Debug, Clone)]
pub struct NetLsd {
    /// Use the dense eigensolver up to this order.
    pub dense_cutoff: usize,
    /// Eigenvalues taken from each end of the spectrum above the cutoff
    /// (the paper requests 150, falling back to ≥ 50).
    pub k_ends: usize,
}

impl Default for NetLsd {
    fn default() -> Self {
        NetLsd { dense_cutoff: 1024, k_ends: 150 }
    }
}

impl NetLsd {
    /// Eigenspectrum (exact or §6.3-approximate) of the graph's normalized
    /// Laplacian.
    pub fn spectrum(&self, g: &Graph, seed: u64) -> Vec<f64> {
        let csr = Csr::from_graph(g);
        if g.n <= self.dense_cutoff {
            symmetric_eigenvalues(&csr.normalized_laplacian(), g.n)
        } else {
            let k = self.k_ends.min(g.n / 4).max(8);
            let mut rng = Pcg64::seed_from_u64(seed ^ 0x7e75d);
            let (low, high) = lanczos_extreme_eigenvalues(
                g.n,
                |x, y| csr.laplacian_matvec(x, y),
                k,
                &mut rng,
            );
            interpolate_spectrum(&low, &high, g.n)
        }
    }

    /// All six ψ variants, 60 j-values each.
    pub fn descriptor(&self, g: &Graph, seed: u64) -> [[f64; N_J]; N_VARIANTS] {
        psi_from_eigenvalues(&self.spectrum(g, seed), g.n as f64)
    }
}

/// [`GraphDescriptor`] adapter for one variant.
#[derive(Debug, Clone)]
pub struct NetLsdDescriptor {
    /// The configured NetLSD engine.
    pub engine: NetLsd,
    /// 0..6 = HN, HE, HC, WN, WE, WC.
    pub variant: usize,
}

impl GraphDescriptor for NetLsdDescriptor {
    fn name(&self) -> String {
        format!("NetLSD-{}", super::psi::VARIANT_NAMES[self.variant])
    }

    fn dim(&self) -> usize {
        N_J
    }

    fn compute(&self, g: &Graph, seed: u64) -> Vec<f64> {
        self.engine.descriptor(g, seed)[self.variant].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::descriptors::psi::j_grid;
    use crate::gen;

    #[test]
    fn complete_graph_heat_trace_closed_form() {
        // K_n: λ = {0, n/(n-1) × (n-1 times)}; heat = 1 + (n-1) e^{-j n/(n-1)}
        let n = 8usize;
        let g = Graph::from_pairs(
            (0..n as u32).flat_map(|i| (i + 1..n as u32).map(move |j| (i, j))),
        );
        let d = NetLsd::default().descriptor(&g, 0);
        let j = j_grid();
        for k in [0, 30, 59] {
            let want = 1.0 + (n as f64 - 1.0) * (-j[k] * n as f64 / (n as f64 - 1.0)).exp();
            assert!((d[0][k] - want).abs() < 1e-9, "j={}", j[k]);
        }
    }

    #[test]
    fn lanczos_path_close_to_dense_on_medium_graph() {
        let mut rng = Pcg64::seed_from_u64(31);
        let g = gen::ba_graph(600, 3, &mut rng);
        let dense = NetLsd { dense_cutoff: 4096, k_ends: 150 }.descriptor(&g, 1);
        let approx = NetLsd { dense_cutoff: 10, k_ends: 100 }.descriptor(&g, 1);
        // HC variant (the recommended one) should agree to a few percent
        for k in 0..N_J {
            let rel = (dense[2][k] - approx[2][k]).abs() / dense[2][k].abs().max(1e-9);
            assert!(rel < 0.08, "j index {k}: {} vs {}", approx[2][k], dense[2][k]);
        }
    }

    #[test]
    fn isomorphic_graphs_same_descriptor() {
        let g1 = Graph::from_pairs([(0, 1), (1, 2), (2, 3), (3, 0)]);
        let g2 = Graph::from_pairs([(2, 0), (0, 3), (3, 1), (1, 2)]); // relabeled C4
        let a = NetLsd::default().descriptor(&g1, 0);
        let b = NetLsd::default().descriptor(&g2, 0);
        for v in 0..N_VARIANTS {
            for k in 0..N_J {
                assert!((a[v][k] - b[v][k]).abs() < 1e-9);
            }
        }
    }
}
