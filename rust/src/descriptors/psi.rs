//! ψ_j evaluation (NetLSD's heat/wave functionals, paper §4.3 Table 8) —
//! the rust mirror of the L1 `psi` kernel, plus the exact-spectrum form.
//!
//! The j-grid (60 log-spaced values in [1e-3, 1], §5.1) must match the
//! python side bit-for-bit in spirit; the runtime cross-checks it against
//! `artifacts/manifest.json`.

/// Number of grid points.
pub const N_J: usize = 60;

/// Number of descriptor variants: {Heat,Wave} × {None,Empty,Complete}.
pub const N_VARIANTS: usize = 6;

/// Variant names in canonical order.
pub const VARIANT_NAMES: [&str; N_VARIANTS] = ["HN", "HE", "HC", "WN", "WE", "WC"];

/// 60 log-spaced values in [1e-3, 1].
pub fn j_grid() -> [f64; N_J] {
    let mut out = [0.0; N_J];
    let (lo, hi) = (-3.0f64, 0.0f64);
    for (k, o) in out.iter_mut().enumerate() {
        *o = 10f64.powf(lo + (hi - lo) * k as f64 / (N_J - 1) as f64);
    }
    out
}

/// Five-term Taylor ψ for all six variants from trace estimates
/// `[tr L⁰, tr L¹, tr L², tr L³, tr L⁴]` (mirror of the L2 kernel).
pub fn psi_from_traces(traces: &[f64; 5], nv: f64) -> [[f64; N_J]; N_VARIANTS] {
    let j = j_grid();
    let mut out = [[0.0; N_J]; N_VARIANTS];
    for (k, &jv) in j.iter().enumerate() {
        let heat = traces[0] - jv * traces[1] + jv * jv / 2.0 * traces[2]
            - jv.powi(3) / 6.0 * traces[3]
            + jv.powi(4) / 24.0 * traces[4];
        let wave = traces[0] - jv * jv / 2.0 * traces[2] + jv.powi(4) / 24.0 * traces[4];
        let nv_safe = nv.max(1.0);
        let heat_c = 1.0 + (nv - 1.0) * (-jv).exp();
        let wave_c = {
            let w = 1.0 + (nv - 1.0) * jv.cos();
            if w.abs() > 1e-6 {
                w
            } else {
                1e-6
            }
        };
        out[0][k] = heat;
        out[1][k] = heat / nv_safe;
        out[2][k] = heat / heat_c;
        out[3][k] = wave;
        out[4][k] = wave / nv_safe;
        out[5][k] = wave / wave_c;
    }
    out
}

/// Truncated-Taylor heat/wave sums for the Fig. 4 comparison.
/// `terms ∈ {3, 4, 5}`; wave ignores the (imaginary) odd terms, so 4-term
/// wave equals 3-term wave (the paper drops it).
pub fn taylor_partial(traces: &[f64; 5], terms: usize) -> ([f64; N_J], [f64; N_J]) {
    assert!((3..=5).contains(&terms));
    let j = j_grid();
    let mut heat = [0.0; N_J];
    let mut wave = [0.0; N_J];
    for (k, &jv) in j.iter().enumerate() {
        let mut h = traces[0] - jv * traces[1] + jv * jv / 2.0 * traces[2];
        let mut w = traces[0] - jv * jv / 2.0 * traces[2];
        if terms >= 4 {
            h -= jv.powi(3) / 6.0 * traces[3];
        }
        if terms >= 5 {
            h += jv.powi(4) / 24.0 * traces[4];
            w += jv.powi(4) / 24.0 * traces[4];
        }
        heat[k] = h;
        wave[k] = w;
    }
    (heat, wave)
}

/// Exact ψ from a full eigenspectrum (NetLSD proper, Table 8).
pub fn psi_from_eigenvalues(eigs: &[f64], nv: f64) -> [[f64; N_J]; N_VARIANTS] {
    let j = j_grid();
    let mut out = [[0.0; N_J]; N_VARIANTS];
    for (k, &jv) in j.iter().enumerate() {
        let mut heat = 0.0;
        let mut wave = 0.0;
        for &l in eigs {
            heat += (-jv * l).exp();
            wave += (jv * l).cos();
        }
        let nv_safe = nv.max(1.0);
        let heat_c = 1.0 + (nv - 1.0) * (-jv).exp();
        let wave_c = {
            let w = 1.0 + (nv - 1.0) * jv.cos();
            if w.abs() > 1e-6 {
                w
            } else {
                1e-6
            }
        };
        out[0][k] = heat;
        out[1][k] = heat / nv_safe;
        out[2][k] = heat / heat_c;
        out[3][k] = wave;
        out[4][k] = wave / nv_safe;
        out[5][k] = wave / wave_c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_logspaced_and_bounded() {
        let j = j_grid();
        assert!((j[0] - 1e-3).abs() < 1e-12);
        assert!((j[N_J - 1] - 1.0).abs() < 1e-12);
        let r0 = j[1] / j[0];
        let r1 = j[31] / j[30];
        assert!((r0 - r1).abs() < 1e-9, "constant ratio");
    }

    #[test]
    fn taylor5_equals_full_psi_unnormalized() {
        let traces = [10.0, 10.0, 14.0, 3.0, 22.0];
        let psi = psi_from_traces(&traces, 10.0);
        let (h5, w5) = taylor_partial(&traces, 5);
        for k in 0..N_J {
            assert!((psi[0][k] - h5[k]).abs() < 1e-12);
            assert!((psi[3][k] - w5[k]).abs() < 1e-12);
        }
    }

    #[test]
    fn taylor_matches_exact_spectrum_at_small_j() {
        // Exact traces of a known spectrum => 5-term Taylor ≈ exact ψ for
        // small j (the premise of SANTA).
        let eigs = [0.0, 0.5, 1.0, 1.5, 2.0];
        let nv = eigs.len() as f64;
        let traces = [
            nv,
            eigs.iter().sum::<f64>(),
            eigs.iter().map(|l| l * l).sum(),
            eigs.iter().map(|l| l.powi(3)).sum(),
            eigs.iter().map(|l| l.powi(4)).sum(),
        ];
        let approx = psi_from_traces(&traces, nv);
        let exact = psi_from_eigenvalues(&eigs, nv);
        let j = j_grid();
        for k in 0..N_J {
            if j[k] <= 0.05 {
                for v in 0..N_VARIANTS {
                    let rel = (approx[v][k] - exact[v][k]).abs() / exact[v][k].abs();
                    assert!(rel < 1e-5, "variant {v} j={} rel={rel}", j[k]);
                }
            }
        }
    }

    #[test]
    fn heat_none_at_zero_j_is_nv() {
        let eigs = [0.0, 1.0, 2.0];
        let psi = psi_from_eigenvalues(&eigs, 3.0);
        // j→1e-3: sum e^{-jλ} ≈ 3 - j*3
        assert!((psi[0][0] - 3.0).abs() < 0.01);
    }

    #[test]
    fn wave_ignores_odd_terms() {
        let traces = [5.0, 5.0, 8.0, 2.0, 12.0];
        let (_, w3) = taylor_partial(&traces, 3);
        let (_, w4) = taylor_partial(&traces, 4);
        assert_eq!(w3, w4);
    }
}
