//! GABE — Graphlet Amounts via Budgeted Estimates (paper §4.1).
//!
//! One pass over the edge stream.  Connected patterns (triangle, path-4,
//! 4-cycle, paw, diamond, 4-clique) are estimated with the reservoir
//! scheme of §3.3; stars come exactly from the degree sequence and the
//! disconnected patterns from Table 4's closed forms.  The final descriptor
//! concatenates the normalized induced counts φ₂‖φ₃‖φ₄ (17 dimensions).

use crate::checkpoint::{Dec, Enc};
use crate::util::rng::Pcg64;

use super::{Budget, GraphDescriptor};
use crate::count::edge_centric::{enumerate_edge, EdgeHits, Scratch};
use crate::count::formulas::{assemble_counts, binom2, binom3, binom4, ConnectedCounts};
use crate::count::overlap::{overlap_inverse, to_induced};
use crate::count::{N_GRAPHLETS, ORDERS};
use crate::graph::adjacency::SampleGraph;
use crate::graph::stream::EdgeStream;
use crate::graph::Graph;
use crate::sampling::window::{EdgeRing, WindowAcc};
use crate::sampling::{
    sample_inclusion_probability, Backend, EstimatorConfig, GraphSketch, MergeableState,
    MergedReservoir, ReservoirAction, Series, Snapshot, Weights, WindowConfig, WindowPolicy,
    WindowedReservoir,
};

// WindowAcc counter indices (one per reservoir-estimated pattern).
const A_TRI: usize = 0;
const A_PATH4: usize = 1;
const A_C4: usize = 2;
const A_PAW: usize = 3;
const A_DIAMOND: usize = 4;
const A_K4: usize = 5;

/// Raw output of one GABE streaming run.
#[derive(Debug, Clone)]
pub struct GabeEstimate {
    /// Estimated non-induced counts `H` in canonical graphlet order.
    pub counts: [f64; N_GRAPHLETS],
    /// Order |V| inferred from the stream (max label + 1).
    pub nv: u64,
    /// `|E|` of the graph the estimate describes (window length under a
    /// sliding window, all-time stream length otherwise).
    pub ne: u64,
    /// Exact degree sequence.
    pub degrees: Vec<u32>,
}

impl GabeEstimate {
    /// Finalize into the 17-dim φ descriptor (rust mirror of the
    /// `gabe_finalize` L2 artifact): `φ = (O⁻¹ H) / C(|V|, order)`.
    pub fn descriptor(&self) -> [f64; N_GRAPHLETS] {
        let induced = to_induced(&self.counts, &overlap_inverse());
        let nv = self.nv as f64;
        let mut out = [0.0; N_GRAPHLETS];
        for i in 0..N_GRAPHLETS {
            let norm = match ORDERS[i] {
                2 => binom2(nv),
                3 => binom3(nv),
                _ => binom4(nv),
            }
            .max(1.0);
            out[i] = induced[i] / norm;
        }
        out
    }

    pub(crate) fn save(&self, out: &mut Enc) {
        for c in &self.counts {
            out.f64(*c);
        }
        out.u64(self.nv);
        out.u64(self.ne);
        out.usize(self.degrees.len());
        for d in &self.degrees {
            out.u32(*d);
        }
    }

    pub(crate) fn load(d: &mut Dec<'_>) -> crate::Result<GabeEstimate> {
        let mut counts = [0.0; N_GRAPHLETS];
        for c in counts.iter_mut() {
            *c = d.f64()?;
        }
        let nv = d.u64()?;
        let ne = d.u64()?;
        let n = d.seq_len(4)?;
        let mut degrees = Vec::with_capacity(n);
        for _ in 0..n {
            degrees.push(d.u32()?);
        }
        Ok(GabeEstimate { counts, nv, ne, degrees })
    }
}

/// Streaming GABE estimator (Algorithm 1 instantiated for the six
/// connected patterns).
///
/// ```
/// use stream_descriptors::descriptors::gabe::GabeEstimator;
/// use stream_descriptors::graph::stream::VecStream;
/// use stream_descriptors::graph::Graph;
///
/// // A triangle hanging off a path: 4 vertices, 4 edges.
/// let g = Graph::from_pairs([(0, 1), (1, 2), (0, 2), (2, 3)]);
/// let mut stream = VecStream::shuffled(g.edges.clone(), 7);
///
/// // Budget ≥ |E| degenerates to the exact algorithm (all weights 1).
/// let est = GabeEstimator::new(g.m()).run(&mut stream);
/// assert_eq!(est.ne, 4);
/// let tri = est.counts[stream_descriptors::count::idx::TRIANGLE];
/// assert!((tri - 1.0).abs() < 1e-9);
///
/// // The 17-dim φ descriptor is finite and normalized.
/// assert!(est.descriptor().iter().all(|x| x.is_finite()));
/// ```
#[derive(Debug, Clone)]
pub struct GabeEstimator {
    cfg: EstimatorConfig,
}

impl GabeEstimator {
    /// Estimator with the given reservoir budget (paper's `b`), GABE's
    /// historical default seed and the reservoir backend — shorthand for
    /// [`GabeEstimator::from_config`], which is the primary constructor.
    pub fn new(budget: usize) -> Self {
        GabeEstimator::from_config(EstimatorConfig::new(budget).with_seed(0x9abe))
    }

    /// Estimator from the shared [`EstimatorConfig`] (ISSUE 8) — budget,
    /// seed, window and [`Backend`] in one place.
    pub fn from_config(cfg: EstimatorConfig) -> Self {
        GabeEstimator { cfg }
    }

    /// The estimator's configuration.
    pub fn config(&self) -> &EstimatorConfig {
        &self.cfg
    }

    /// Override the reservoir RNG / sketch hash seed.
    ///
    /// Note: delegating shim over [`EstimatorConfig::with_seed`]; prefer
    /// building an [`EstimatorConfig`] and [`GabeEstimator::from_config`].
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.cfg = self.cfg.with_seed(seed);
        self
    }

    /// Set the window policy and snapshot cadence (ISSUE 5).  The default
    /// [`WindowPolicy::None`] reproduces the paper's full-history run
    /// bit-for-bit.
    ///
    /// Note: delegating shim over [`EstimatorConfig::with_window`]; prefer
    /// building an [`EstimatorConfig`] and [`GabeEstimator::from_config`].
    pub fn with_window(mut self, window: WindowConfig) -> Self {
        self.cfg = self.cfg.with_window(window);
        self
    }

    /// Select the estimation backend (reservoir or sketch).
    ///
    /// Note: delegating shim over [`EstimatorConfig::with_backend`]; prefer
    /// building an [`EstimatorConfig`] and [`GabeEstimator::from_config`].
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.cfg = self.cfg.with_backend(backend);
        self
    }

    /// Consume a stream and produce count estimates (single pass, ≤ `b`
    /// stored edges, `O(b log b)` per edge — constraints C1–C3).
    ///
    #[doc = include_str!("run_doc.md")]
    pub fn run(&self, stream: &mut impl EdgeStream) -> GabeEstimate {
        self.try_run(stream).expect("gabe: edge stream failed")
    }

    /// **Primary entry point**: consume a stream and produce count
    /// estimates, surfacing stream I/O failures as errors.
    /// [`GabeEstimator::run`] is the panicking convenience wrapper.
    pub fn try_run(&self, stream: &mut impl EdgeStream) -> crate::Result<GabeEstimate> {
        Ok(self.try_run_series(stream)?.last)
    }

    /// Run and return the full descriptor time series: one snapshot per
    /// `stride` arrivals (see [`WindowConfig`]) plus the final estimate.
    ///
    #[doc = include_str!("run_doc.md")]
    pub fn run_series(&self, stream: &mut impl EdgeStream) -> Series<GabeEstimate> {
        self.try_run_series(stream).expect("gabe: edge stream failed")
    }

    /// **Primary entry point** for time series: like
    /// [`run_series`](GabeEstimator::run_series), surfacing stream I/O
    /// failures as errors instead of panicking.
    pub fn try_run_series(
        &self,
        stream: &mut impl EdgeStream,
    ) -> crate::Result<Series<GabeEstimate>> {
        self.cfg.validate()?;
        let mut state = GabeState::from_config(&self.cfg);
        while let Some(e) = stream.next_edge() {
            state.push(e);
        }
        if let Some(e) = stream.take_error() {
            return Err(e.context("gabe stream truncated"));
        }
        let snapshots = state.take_snapshots();
        Ok(Series { snapshots, last: state.finish() })
    }
}

/// Incremental GABE estimator state — the worker-side API the coordinator
/// pushes edge chunks into.
#[derive(Debug)]
pub struct GabeState {
    budget: usize,
    reservoir: WindowedReservoir,
    sample: SampleGraph,
    /// Exact degrees — windowed (last `w` edges) in sliding mode,
    /// all-time otherwise.
    degrees: Vec<u32>,
    /// Sliding mode's degree clock: the last `w` stream edges (`None`
    /// for full-history and decay runs).
    ring: Option<EdgeRing>,
    hits: EdgeHits,
    scratch: Scratch,
    acc: WindowAcc<6>,
    expired: Vec<crate::graph::Edge>,
    window: WindowConfig,
    snapshots: Vec<Snapshot<GabeEstimate>>,
    ne: u64,
    /// `Some` iff running on [`Backend::Sketch`] (ISSUE 8): the bucket
    /// matrices that replace the reservoir + sample graph.
    sketch: Option<GraphSketch>,
}

impl GabeState {
    /// Full-history state (the paper's setting).
    pub fn new(budget: usize, seed: u64) -> Self {
        Self::with_window(budget, seed, WindowConfig::default())
    }

    /// State under a window policy + snapshot cadence (ISSUE 5).  The
    /// policy must have been validated (see [`WindowConfig::validate`]).
    pub fn with_window(budget: usize, seed: u64, window: WindowConfig) -> Self {
        Self::from_config(&EstimatorConfig::new(budget).with_seed(seed).with_window(window))
    }

    /// State from the shared [`EstimatorConfig`] (the primary
    /// constructor).  The config must have been validated (see
    /// [`EstimatorConfig::validate`]).
    pub fn from_config(cfg: &EstimatorConfig) -> Self {
        let b = cfg.budget.max(1);
        let ring = match cfg.window.policy {
            WindowPolicy::Sliding { w } => Some(EdgeRing::new(w)),
            _ => None,
        };
        let sketch = match cfg.backend {
            Backend::Sketch { width, depth } => Some(GraphSketch::new(width, depth, cfg.seed)),
            Backend::Reservoir => None,
        };
        GabeState {
            budget: b,
            reservoir: WindowedReservoir::new(cfg.window.policy, b, Pcg64::seed_from_u64(cfg.seed)),
            sample: SampleGraph::new(),
            degrees: Vec::new(),
            ring,
            hits: EdgeHits::default(),
            scratch: Scratch::default(),
            acc: WindowAcc::new(cfg.window.policy),
            expired: Vec::new(),
            window: cfg.window,
            snapshots: Vec::new(),
            ne: 0,
            sketch,
        }
    }

    /// Process one arriving edge (Algorithm 1 body, windowed).
    pub fn push(&mut self, e: crate::graph::Edge) {
        if let Some(sk) = &mut self.sketch {
            // sketch backend: O(1) bucket update, exact degrees, no
            // reservoir bookkeeping (validation rejects windows here)
            self.ne += 1;
            let (u, v) = (e.u, e.v);
            if self.degrees.len() <= v as usize {
                self.degrees.resize(v as usize + 1, 0);
            }
            self.degrees[u as usize] += 1;
            self.degrees[v as usize] += 1;
            sk.update(u, v);
            self.maybe_snapshot();
            return;
        }
        self.ne += 1;
        self.acc.tick();
        // phase 1: advance the window clock; aged-out sampled edges leave
        // the sample graph before any pattern is enumerated
        let t_eff = self.reservoir.arrive(&mut self.expired);
        for old in self.expired.drain(..) {
            self.sample.remove(old.u, old.v);
        }

        let (u, v) = (e.u, e.v);
        if self.degrees.len() <= v as usize {
            self.degrees.resize(v as usize + 1, 0);
        }
        self.degrees[u as usize] += 1;
        self.degrees[v as usize] += 1;
        if let Some(ring) = &mut self.ring {
            if let Some(old) = ring.push(e) {
                self.degrees[old.u as usize] -= 1;
                self.degrees[old.v as usize] -= 1;
            }
        }

        if !self.sample.insert(u, v) {
            // duplicate stream edge: count nothing.  Full-history mode
            // still offers it (the paper path's behavior, kept
            // bit-compatible); windowed reservoirs skip the offer — a
            // second sampled copy of an edge already in the sample would
            // desync eviction from the sample graph (churned/windowed
            // streams legitimately re-emit edges).
            if !self.window.policy.is_windowed() {
                self.reservoir.offer(e);
            }
            self.maybe_snapshot();
            return;
        }
        let w = Weights::at(t_eff, self.budget);
        enumerate_edge(&self.sample, u, v, &mut self.hits, &mut self.scratch);
        self.acc.credit(A_TRI, self.hits.triangles() as f64 * w.w3);
        self.acc.credit(A_PATH4, self.hits.path4() as f64 * w.w3);
        self.acc.credit(A_C4, self.hits.c4 as f64 * w.w4);
        self.acc.credit(A_PAW, self.hits.paw() as f64 * w.w4);
        self.acc.credit(A_DIAMOND, self.hits.diamond() as f64 * w.w5);
        self.acc.credit(A_K4, self.hits.k4 as f64 * w.w6);

        match self.reservoir.offer(e) {
            ReservoirAction::Stored => {}
            ReservoirAction::Replaced(old) => {
                self.sample.remove(old.u, old.v);
            }
            ReservoirAction::Discarded => {
                self.sample.remove(u, v);
            }
        }
        self.maybe_snapshot();
    }

    /// Build the estimate from the current counters, taking ownership of
    /// `degrees` (the snapshot path clones; `finish` moves).
    fn estimate_with(&self, degrees: Vec<u32>) -> GabeEstimate {
        let nv = degrees.len() as u64;
        let c = match &self.sketch {
            Some(sk) => sk.connected_counts(),
            None => {
                let vals = self.acc.values();
                ConnectedCounts {
                    triangle: vals[A_TRI],
                    path4: vals[A_PATH4],
                    cycle4: vals[A_C4],
                    paw: vals[A_PAW],
                    diamond: vals[A_DIAMOND],
                    k4: vals[A_K4],
                }
            }
        };
        let ne = self.window.policy.described_len(self.ne);
        let counts = assemble_counts(nv as f64, ne as f64, &degrees, &c);
        GabeEstimate { counts, nv, ne, degrees }
    }

    /// The estimate as of the current arrival (snapshot path).
    fn estimate_now(&self) -> GabeEstimate {
        self.estimate_with(self.degrees.clone())
    }

    fn maybe_snapshot(&mut self) {
        if self.window.snapshot_due(self.ne) {
            let estimate = self.estimate_now();
            self.snapshots.push(Snapshot { t: self.ne, estimate });
        }
    }

    /// Drain the snapshots recorded so far (coordinator barrier merge).
    pub fn take_snapshots(&mut self) -> Vec<Snapshot<GabeEstimate>> {
        std::mem::take(&mut self.snapshots)
    }

    /// Finalize into count estimates.
    pub fn finish(mut self) -> GabeEstimate {
        let degrees = std::mem::take(&mut self.degrees);
        self.estimate_with(degrees)
    }

    /// Serialize the complete estimator state (ISSUE 7).  Scratch buffers
    /// (`hits`, `scratch`, `expired`) are empty between arrivals and
    /// restore as defaults; everything else — sampler, sample graph,
    /// windowed counters, degree clock, recorded snapshots — is captured
    /// so a resumed run is bit-for-bit the uninterrupted one.
    pub(crate) fn save(&self, out: &mut Enc) {
        out.usize(self.budget);
        self.window.save(out);
        self.reservoir.save(out);
        self.sample.save(out);
        out.usize(self.degrees.len());
        for deg in &self.degrees {
            out.u32(*deg);
        }
        match &self.ring {
            None => out.u8(0),
            Some(r) => {
                out.u8(1);
                r.save(out);
            }
        }
        self.acc.save(out);
        out.usize(self.snapshots.len());
        for s in &self.snapshots {
            out.u64(s.t);
            s.estimate.save(out);
        }
        out.u64(self.ne);
        match &self.sketch {
            None => out.u8(0),
            Some(sk) => {
                out.u8(1);
                sk.save(out);
            }
        }
    }

    /// Rebuild from [`GabeState::save`] bytes.
    pub(crate) fn load(d: &mut Dec<'_>) -> crate::Result<GabeState> {
        let budget = d.usize()?;
        crate::ensure!(budget > 0, "gabe checkpoint: zero budget");
        let window = WindowConfig::load(d)?;
        let reservoir = WindowedReservoir::load(d)?;
        let sample = SampleGraph::load(d)?;
        let n = d.seq_len(4)?;
        let mut degrees = Vec::with_capacity(n);
        for _ in 0..n {
            degrees.push(d.u32()?);
        }
        let ring = match d.u8()? {
            0 => None,
            1 => Some(EdgeRing::load(d)?),
            tag => return Err(crate::anyhow!("gabe checkpoint: unknown ring tag {tag}")),
        };
        let acc = WindowAcc::load(d)?;
        let n_snaps = d.seq_len(8)?;
        let mut snapshots = Vec::with_capacity(n_snaps);
        for _ in 0..n_snaps {
            let t = d.u64()?;
            let estimate = GabeEstimate::load(d)?;
            snapshots.push(Snapshot { t, estimate });
        }
        let ne = d.u64()?;
        let sketch = match d.u8()? {
            0 => None,
            1 => Some(GraphSketch::load(d)?),
            tag => return Err(crate::anyhow!("gabe checkpoint: unknown sketch tag {tag}")),
        };
        Ok(GabeState {
            budget,
            reservoir,
            sample,
            degrees,
            ring,
            hits: EdgeHits::default(),
            scratch: Scratch::default(),
            acc,
            expired: Vec::new(),
            window,
            snapshots,
            ne,
            sketch,
        })
    }

    /// Entrywise merge of a sketch-backend shard into this one
    /// (coordinator shard mode): bucket matrices add exactly, degrees
    /// and the edge clock sum.  Errors on reservoir states — tombstoned
    /// reservoirs are not mergeable (ROADMAP, sharding item).
    pub(crate) fn merge_from(&mut self, other: &GabeState) -> crate::Result<()> {
        let Some(sk) = &mut self.sketch else {
            return Err(crate::anyhow!("gabe merge: reservoir states are not mergeable"));
        };
        let Some(osk) = &other.sketch else {
            return Err(crate::anyhow!("gabe merge: backend mismatch"));
        };
        sk.merge(osk)?;
        if self.degrees.len() < other.degrees.len() {
            self.degrees.resize(other.degrees.len(), 0);
        }
        for (i, d) in other.degrees.iter().enumerate() {
            self.degrees[i] += d;
        }
        self.ne += other.ne;
        Ok(())
    }

    /// Merge K *reservoir*-backend shard states into one estimate
    /// (ISSUE 10, the statistical half of [`crate::sampling::merge`]).
    ///
    /// The shard reservoirs are lifted into [`MergedReservoir`]s under
    /// `merge_seed` and folded into one near-uniform sample of the
    /// concatenated stream; the sample is then *replayed* through a
    /// fresh state whose budget covers it (every weight 1, no RNG
    /// draws), giving raw sample-graph pattern counts which are rescaled
    /// by the inverse inclusion probability of each pattern's edge count
    /// ([`sample_inclusion_probability`]) — unbiased by linearity, with
    /// variance governed by the merged budget rather than the shard
    /// count.  Degrees and the edge clock sum exactly across shards.
    pub(crate) fn merge_reservoir_shards(
        states: &[GabeState],
        merge_seed: u64,
    ) -> crate::Result<GabeEstimate> {
        crate::ensure!(!states.is_empty(), "gabe shard merge: no shard states");
        let mut merged: Option<MergedReservoir> = None;
        let mut degrees: Vec<u32> = Vec::new();
        let mut ne = 0u64;
        for s in states {
            crate::ensure!(
                s.sketch.is_none(),
                "gabe shard merge: sketch states merge entrywise, not by subsampling"
            );
            crate::ensure!(
                matches!(s.window.policy, WindowPolicy::None),
                "gabe shard merge: windowed states cannot be merged"
            );
            let WindowedReservoir::Full(r) = &s.reservoir else {
                return Err(crate::anyhow!(
                    "gabe shard merge: windowed reservoir in an unwindowed state"
                ));
            };
            let lifted = MergedReservoir::from_reservoir(r, merge_seed);
            merged = Some(match merged {
                None => lifted,
                Some(mut m) => {
                    m.merge_state(&lifted)?;
                    m
                }
            });
            if degrees.len() < s.degrees.len() {
                degrees.resize(s.degrees.len(), 0);
            }
            for (i, d) in s.degrees.iter().enumerate() {
                degrees[i] += d;
            }
            ne += s.ne;
        }
        let (sample, t_total) = merged.expect("states is non-empty").into_sample();
        let raw = replay_sample_counts(&sample);
        let p = |f_edges: usize| sample_inclusion_probability(f_edges, t_total, sample.len());
        let rescale = |raw: f64, p: f64| if raw == 0.0 { 0.0 } else { raw / p };
        let c = ConnectedCounts {
            triangle: rescale(raw.triangle, p(3)),
            path4: rescale(raw.path4, p(3)),
            cycle4: rescale(raw.cycle4, p(4)),
            paw: rescale(raw.paw, p(4)),
            diamond: rescale(raw.diamond, p(5)),
            k4: rescale(raw.k4, p(6)),
        };
        let nv = degrees.len() as u64;
        let counts = assemble_counts(nv as f64, ne as f64, &degrees, &c);
        Ok(GabeEstimate { counts, nv, ne, degrees })
    }

    /// Approximate resident bytes of the estimator state — the memory
    /// axis of the `repro sketch` accuracy-vs-memory comparison.
    pub fn resident_bytes(&self) -> usize {
        let degrees = self.degrees.len() * 4;
        match &self.sketch {
            Some(sk) => sk.bytes() + degrees,
            None => {
                self.budget * 8
                    + self.sample.arena_len() * 4
                    + self.sample.intern_capacity() * 8
                    + degrees
            }
        }
    }
}

/// Raw connected-pattern counts of a merged sample: replay the edges
/// through a fresh state whose budget covers them all — every offer
/// stores, every weight is exactly 1, no RNG draw happens — so the
/// accumulators end up holding the sample graph's pattern counts.
fn replay_sample_counts(sample: &[crate::graph::Edge]) -> ConnectedCounts {
    let mut st = GabeState::from_config(&EstimatorConfig::new(sample.len().max(1)));
    for &e in sample {
        st.push(e);
    }
    let vals = st.acc.values();
    ConnectedCounts {
        triangle: vals[A_TRI],
        path4: vals[A_PATH4],
        cycle4: vals[A_C4],
        paw: vals[A_PAW],
        diamond: vals[A_DIAMOND],
        k4: vals[A_K4],
    }
}

/// [`GraphDescriptor`] adapter: shuffle → stream → finalize.
#[derive(Debug, Clone)]
pub struct Gabe {
    /// Reservoir budget to resolve against each graph's `|E|`.
    pub budget: Budget,
}

impl GraphDescriptor for Gabe {
    fn name(&self) -> String {
        match self.budget {
            Budget::Fraction(f) => format!("GABE@{f}"),
            Budget::Edges(b) => format!("GABE@b={b}"),
            Budget::Exact => "GABE@exact".into(),
        }
    }

    fn dim(&self) -> usize {
        N_GRAPHLETS
    }

    fn compute(&self, g: &Graph, seed: u64) -> Vec<f64> {
        let mut stream = super::stream_of(g, seed);
        let b = super::resolve_budget(self.budget, &stream)
            .expect("VecStream always has a len hint");
        let est = GabeEstimator::new(b).with_seed(seed ^ 0x6a6e).run(&mut stream);
        est.descriptor().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute::subgraph_census;
    use crate::count::idx;
    use crate::gen;
    use crate::graph::stream::VecStream;

    /// ISSUE 4: the direct estimator path surfaces mid-stream I/O errors
    /// instead of estimating from a silently truncated prefix.
    #[test]
    fn try_run_fails_on_midstream_error() {
        use crate::graph::stream::{FailAfter, ReaderStream};
        let mut text = String::new();
        for i in 0..40u32 {
            text.push_str(&format!("{} {}\n", i, i + 1));
        }
        let mut s =
            ReaderStream::new(std::io::BufReader::new(FailAfter::new(text.into_bytes(), 80)));
        let err = GabeEstimator::new(100)
            .try_run(&mut s)
            .expect_err("mid-file failure must not yield an estimate");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    /// With b ≥ |E| every weight is 1 and the estimate must be exact.
    #[test]
    fn exact_mode_matches_brute_force() {
        let mut rng = Pcg64::seed_from_u64(5);
        for trial in 0..8 {
            let g = gen::er_graph(14, 30 + trial, &mut rng);
            let want = subgraph_census(&g);
            let mut s = VecStream::shuffled(g.edges.clone(), trial as u64);
            let est = GabeEstimator::new(g.m() + 1).run(&mut s);
            for i in 0..N_GRAPHLETS {
                assert!(
                    (est.counts[i] - want[i]).abs() < 1e-6,
                    "trial {trial} graphlet {i}: {} vs {}",
                    est.counts[i],
                    want[i]
                );
            }
        }
    }

    /// Stream order must not change the exact-mode answer.
    #[test]
    fn exact_mode_order_invariant() {
        let mut rng = Pcg64::seed_from_u64(6);
        let g = gen::powerlaw_cluster_graph(30, 3, 0.6, &mut rng);
        let mut base: Option<[f64; N_GRAPHLETS]> = None;
        for seed in 0..5 {
            let mut s = VecStream::shuffled(g.edges.clone(), seed);
            let est = GabeEstimator::new(g.m()).run(&mut s);
            match &base {
                None => base = Some(est.counts),
                Some(b) => {
                    for i in 0..N_GRAPHLETS {
                        assert!((b[i] - est.counts[i]).abs() < 1e-6, "seed {seed}");
                    }
                }
            }
        }
    }

    /// Theorem 1 (unbiasedness): the estimator mean over many runs must be
    /// close to the true count even with a small budget.
    #[test]
    fn budgeted_estimates_are_unbiased() {
        let mut rng = Pcg64::seed_from_u64(7);
        let g = gen::powerlaw_cluster_graph(60, 4, 0.7, &mut rng);
        let want = subgraph_census(&g);
        let runs = 600;
        let b = g.m() / 2;
        let mut mean = [0.0f64; N_GRAPHLETS];
        for r in 0..runs {
            let mut s = VecStream::shuffled(g.edges.clone(), r);
            let est = GabeEstimator::new(b).with_seed(r ^ 0xdead).run(&mut s);
            for i in 0..N_GRAPHLETS {
                mean[i] += est.counts[i] / runs as f64;
            }
        }
        for i in [idx::TRIANGLE, idx::PATH4, idx::CYCLE4, idx::PAW] {
            let rel = (mean[i] - want[i]).abs() / want[i].max(1.0);
            assert!(rel < 0.08, "graphlet {i}: mean {} vs true {}", mean[i], want[i]);
        }
    }

    #[test]
    fn descriptor_is_normalized_and_finite() {
        let mut rng = Pcg64::seed_from_u64(8);
        let g = gen::er_graph(200, 800, &mut rng);
        let mut s = VecStream::shuffled(g.edges.clone(), 3);
        let est = GabeEstimator::new(200).run(&mut s);
        let d = est.descriptor();
        assert!(d.iter().all(|x| x.is_finite()));
        // φ2 entries: induced edge share ≈ density ∈ (0,1)
        assert!(d[idx::EDGE] > 0.0 && d[idx::EDGE] < 1.0);
        assert!((d[idx::E2] + d[idx::EDGE] - 1.0).abs() < 1e-9);
    }

    /// ISSUE 5 differential: `WindowPolicy::None` and `Sliding{w ≥ |E|}`
    /// must both reproduce the full-history estimator bit-for-bit — same
    /// RNG draws, same actions, same float operation order.
    #[test]
    fn window_none_and_huge_sliding_are_bit_identical_to_full_history() {
        let mut rng = Pcg64::seed_from_u64(31);
        let g = gen::powerlaw_cluster_graph(120, 3, 0.5, &mut rng);
        let b = g.m() / 3; // budgeted: the reservoir genuinely randomizes
        let mut s = VecStream::shuffled(g.edges.clone(), 2);
        let base = GabeEstimator::new(b).with_seed(77).run(&mut s);
        for policy in [
            WindowPolicy::None,
            WindowPolicy::Sliding { w: g.m() },
            WindowPolicy::Sliding { w: g.m() * 10 },
        ] {
            let mut s = VecStream::shuffled(g.edges.clone(), 2);
            let est = GabeEstimator::new(b)
                .with_seed(77)
                .with_window(WindowConfig::new(policy))
                .run(&mut s);
            assert_eq!(est.counts, base.counts, "{policy:?} diverged");
            assert_eq!(est.degrees, base.degrees);
            assert_eq!((est.nv, est.ne), (base.nv, base.ne));
        }
    }

    /// ISSUE 5 eviction census: under a sliding window the sample graph
    /// and reservoir never hold an edge older than `w`, and both stay in
    /// lock-step.
    #[test]
    fn sliding_sample_never_holds_an_edge_older_than_w() {
        use crate::sampling::WindowedReservoir;
        let mut rng = Pcg64::seed_from_u64(32);
        let g = gen::ba_graph(400, 3, &mut rng);
        let w = 150usize;
        let policy = WindowPolicy::Sliding { w };
        let mut state = GabeState::with_window(60, 5, WindowConfig::new(policy));
        let stream = VecStream::shuffled(g.edges.clone(), 4);
        for (i, &e) in stream.edges().iter().enumerate() {
            state.push(e);
            let t = i + 1;
            let WindowedReservoir::Sliding(r) = &state.reservoir else { panic!() };
            assert_eq!(r.len(), state.sample.m(), "sample/reservoir out of lock-step");
            for (edge, arrival) in r.entries() {
                assert!(arrival + w > t, "edge from t={arrival} alive at t={t}");
                assert!(state.sample.has_edge(edge.u, edge.v));
            }
        }
        // windowed degrees cover exactly the last w edges
        let tail = &stream.edges()[g.m() - w..];
        let mut want = vec![0u32; state.degrees.len()];
        for e in tail {
            want[e.u as usize] += 1;
            want[e.v as usize] += 1;
        }
        assert_eq!(state.degrees, want);
        let est = state.finish();
        assert_eq!(est.ne, w as u64);
    }

    /// ISSUE 5 regression (review finding): a stream that re-emits edges —
    /// churned streams legitimately do — must keep the sliding reservoir
    /// and the sample graph in lock-step.  Before the fix, a duplicate of
    /// a sampled edge stored a second reservoir copy whose later
    /// expiry/eviction removed the edge from the sample while the other
    /// copy stayed sampled.
    #[test]
    fn sliding_survives_duplicate_stream_edges() {
        use crate::sampling::WindowedReservoir;
        let mut rng = Pcg64::seed_from_u64(35);
        let g = gen::powerlaw_cluster_graph(80, 3, 0.5, &mut rng);
        // the same edge set twice = every edge re-arrives once
        let stream = gen::churned_stream(&[&g, &g], 2);
        let w = g.m() / 2;
        let policy = WindowConfig::new(WindowPolicy::Sliding { w });
        let mut state = GabeState::with_window(g.m() / 4, 11, policy);
        for (i, &e) in stream.iter().enumerate() {
            state.push(e);
            let WindowedReservoir::Sliding(r) = &state.reservoir else { panic!() };
            assert_eq!(r.len(), state.sample.m(), "desync after edge {i}");
            for (edge, arrival) in r.entries() {
                assert!(arrival + w > i + 1);
                assert!(state.sample.has_edge(edge.u, edge.v));
            }
        }
        let est = state.finish();
        assert!(est.counts.iter().all(|c| c.is_finite()));
    }

    /// Snapshots form a time series at the configured stride, and under a
    /// sliding window each one describes the window, not the prefix.
    #[test]
    fn snapshot_series_has_stride_cadence() {
        let mut rng = Pcg64::seed_from_u64(33);
        let g = gen::er_graph(80, 400, &mut rng);
        let window = WindowConfig::new(WindowPolicy::Sliding { w: 100 }).with_stride(50);
        let mut s = VecStream::shuffled(g.edges.clone(), 1);
        let series = GabeEstimator::new(64).with_window(window).run_series(&mut s);
        assert_eq!(series.snapshots.len(), g.m() / 50);
        for (k, snap) in series.snapshots.iter().enumerate() {
            assert_eq!(snap.t, 50 * (k as u64 + 1));
            assert_eq!(snap.estimate.ne, snap.t.min(100));
            assert!(snap.estimate.counts.iter().all(|c| c.is_finite()));
        }
        assert_eq!(series.last.ne, 100);
    }

    /// Decay mode runs, stays finite, and its connected-pattern counts
    /// track the decayed credit mass rather than the all-time totals.
    #[test]
    fn decay_mode_estimates_are_finite_and_bounded() {
        let mut rng = Pcg64::seed_from_u64(34);
        let g = gen::powerlaw_cluster_graph(150, 4, 0.5, &mut rng);
        let mut s = VecStream::shuffled(g.edges.clone(), 9);
        let full = GabeEstimator::new(g.m()).with_seed(3).run(&mut s);
        let mut s = VecStream::shuffled(g.edges.clone(), 9);
        let window = WindowConfig::new(WindowPolicy::Decay { half_life: g.m() as f64 / 8.0 });
        let decayed = GabeEstimator::new(g.m()).with_seed(3).with_window(window).run(&mut s);
        assert!(decayed.counts.iter().all(|c| c.is_finite()));
        // decayed credit mass is strictly below the all-time total
        assert!(
            decayed.counts[idx::TRIANGLE] < full.counts[idx::TRIANGLE],
            "{} !< {}",
            decayed.counts[idx::TRIANGLE],
            full.counts[idx::TRIANGLE]
        );
        assert!(decayed.counts[idx::TRIANGLE] > 0.0);
    }

    #[test]
    fn respects_budget() {
        let mut rng = Pcg64::seed_from_u64(9);
        let g = gen::ba_graph(500, 3, &mut rng);
        let mut s = VecStream::shuffled(g.edges.clone(), 4);
        // run with tiny budget: must not blow up and must see all degrees
        let est = GabeEstimator::new(16).run(&mut s);
        assert_eq!(est.ne as usize, g.m());
        assert_eq!(est.degrees, g.degrees());
    }

    /// ISSUE 10: with budget ≥ |E| every shard reservoir holds its whole
    /// shard, the merged sample is the entire edge set, every inclusion
    /// probability is 1 and the shard merge must reproduce the exact
    /// counts — the deterministic anchor of the replay-and-rescale path.
    #[test]
    fn shard_merge_with_full_budget_is_exact() {
        let mut rng = Pcg64::seed_from_u64(21);
        let g = gen::powerlaw_cluster_graph(60, 3, 0.5, &mut rng);
        let want = subgraph_census(&g);
        for k in [1usize, 3, 4] {
            let cfg = EstimatorConfig::new(g.m() + 1);
            let mut shards: Vec<GabeState> =
                (0..k).map(|_| GabeState::from_config(&cfg)).collect();
            for (i, &e) in g.edges.iter().enumerate() {
                shards[i % k].push(e);
            }
            let est = GabeState::merge_reservoir_shards(&shards, 0xfeed).unwrap();
            for i in 0..N_GRAPHLETS {
                assert!(
                    (est.counts[i] - want[i]).abs() < 1e-6,
                    "k={k} graphlet {i}: {} vs {}",
                    est.counts[i],
                    want[i]
                );
            }
            assert_eq!(est.degrees, g.degrees());
            assert_eq!(est.ne as usize, g.m());
        }
    }

    /// Shard merge rejects sketch and windowed states by name.
    #[test]
    fn shard_merge_rejects_sketch_and_windowed_states() {
        let sketchy = GabeState::from_config(
            &EstimatorConfig::new(8).with_backend(Backend::sketch_default()),
        );
        let err = GabeState::merge_reservoir_shards(&[sketchy], 1).unwrap_err();
        assert!(err.to_string().contains("entrywise"), "{err}");
        let windowed = GabeState::from_config(
            &EstimatorConfig::new(8)
                .with_window(WindowConfig::new(WindowPolicy::Sliding { w: 4 })),
        );
        let err = GabeState::merge_reservoir_shards(&[windowed], 1).unwrap_err();
        assert!(err.to_string().contains("windowed"), "{err}");
    }
}
