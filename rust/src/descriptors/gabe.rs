//! GABE — Graphlet Amounts via Budgeted Estimates (paper §4.1).
//!
//! One pass over the edge stream.  Connected patterns (triangle, path-4,
//! 4-cycle, paw, diamond, 4-clique) are estimated with the reservoir
//! scheme of §3.3; stars come exactly from the degree sequence and the
//! disconnected patterns from Table 4's closed forms.  The final descriptor
//! concatenates the normalized induced counts φ₂‖φ₃‖φ₄ (17 dimensions).

use crate::util::rng::Pcg64;

use super::{Budget, GraphDescriptor};
use crate::count::edge_centric::{enumerate_edge, EdgeHits, Scratch};
use crate::count::formulas::{assemble_counts, binom2, binom3, binom4, ConnectedCounts};
use crate::count::overlap::{overlap_inverse, to_induced};
use crate::count::{N_GRAPHLETS, ORDERS};
use crate::graph::adjacency::SampleGraph;
use crate::graph::stream::EdgeStream;
use crate::graph::Graph;
use crate::sampling::{Reservoir, ReservoirAction, Weights};

/// Raw output of one GABE streaming run.
#[derive(Debug, Clone)]
pub struct GabeEstimate {
    /// Estimated non-induced counts `H` in canonical graphlet order.
    pub counts: [f64; N_GRAPHLETS],
    /// Order |V| inferred from the stream (max label + 1).
    pub nv: u64,
    /// Size |E| (stream length).
    pub ne: u64,
    /// Exact degree sequence.
    pub degrees: Vec<u32>,
}

impl GabeEstimate {
    /// Finalize into the 17-dim φ descriptor (rust mirror of the
    /// `gabe_finalize` L2 artifact): `φ = (O⁻¹ H) / C(|V|, order)`.
    pub fn descriptor(&self) -> [f64; N_GRAPHLETS] {
        let induced = to_induced(&self.counts, &overlap_inverse());
        let nv = self.nv as f64;
        let mut out = [0.0; N_GRAPHLETS];
        for i in 0..N_GRAPHLETS {
            let norm = match ORDERS[i] {
                2 => binom2(nv),
                3 => binom3(nv),
                _ => binom4(nv),
            }
            .max(1.0);
            out[i] = induced[i] / norm;
        }
        out
    }
}

/// Streaming GABE estimator (Algorithm 1 instantiated for the six
/// connected patterns).
#[derive(Debug, Clone)]
pub struct GabeEstimator {
    budget: usize,
    seed: u64,
}

impl GabeEstimator {
    pub fn new(budget: usize) -> Self {
        GabeEstimator { budget, seed: 0x9abe }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Consume a stream and produce count estimates (single pass, ≤ `b`
    /// stored edges, `O(b log b)` per edge — constraints C1–C3).
    ///
    /// # Panics
    ///
    /// Panics when the stream records an I/O failure (`EdgeStream::
    /// take_error`) — estimates over a silently truncated prefix must
    /// never be returned as if complete.  Use [`GabeEstimator::try_run`]
    /// to handle stream failures as errors.
    pub fn run(&self, stream: &mut impl EdgeStream) -> GabeEstimate {
        self.try_run(stream).expect("gabe: edge stream failed")
    }

    /// Like [`GabeEstimator::run`], surfacing stream I/O failures as
    /// errors instead of panicking.
    pub fn try_run(&self, stream: &mut impl EdgeStream) -> crate::Result<GabeEstimate> {
        let mut state = GabeState::new(self.budget, self.seed);
        while let Some(e) = stream.next_edge() {
            state.push(e);
        }
        if let Some(e) = stream.take_error() {
            return Err(e.context("gabe stream truncated"));
        }
        Ok(state.finish())
    }
}

/// Incremental GABE estimator state — the worker-side API the coordinator
/// pushes edge chunks into.
#[derive(Debug)]
pub struct GabeState {
    budget: usize,
    reservoir: Reservoir,
    sample: SampleGraph,
    degrees: Vec<u32>,
    hits: EdgeHits,
    scratch: Scratch,
    c: ConnectedCounts,
    ne: u64,
}

impl GabeState {
    pub fn new(budget: usize, seed: u64) -> Self {
        let b = budget.max(1);
        GabeState {
            budget: b,
            reservoir: Reservoir::new(b, Pcg64::seed_from_u64(seed)),
            sample: SampleGraph::new(),
            degrees: Vec::new(),
            hits: EdgeHits::default(),
            scratch: Scratch::default(),
            c: ConnectedCounts::default(),
            ne: 0,
        }
    }

    /// Process one arriving edge (Algorithm 1 body).
    pub fn push(&mut self, e: crate::graph::Edge) {
        self.ne += 1;
        let (u, v) = (e.u, e.v);
        if self.degrees.len() <= v as usize {
            self.degrees.resize(v as usize + 1, 0);
        }
        self.degrees[u as usize] += 1;
        self.degrees[v as usize] += 1;

        let t = self.reservoir.t() + 1; // arrival index of e_t
        if !self.sample.insert(u, v) {
            // duplicate stream edge (preprocessing should prevent this):
            // count nothing, keep reservoir time consistent.
            self.reservoir.offer(e);
            return;
        }
        let w = Weights::at(t, self.budget);
        enumerate_edge(&self.sample, u, v, &mut self.hits, &mut self.scratch);
        self.c.triangle += self.hits.triangles() as f64 * w.w3;
        self.c.path4 += self.hits.path4() as f64 * w.w3;
        self.c.cycle4 += self.hits.c4 as f64 * w.w4;
        self.c.paw += self.hits.paw() as f64 * w.w4;
        self.c.diamond += self.hits.diamond() as f64 * w.w5;
        self.c.k4 += self.hits.k4 as f64 * w.w6;

        match self.reservoir.offer(e) {
            ReservoirAction::Stored => {}
            ReservoirAction::Replaced(old) => {
                self.sample.remove(old.u, old.v);
            }
            ReservoirAction::Discarded => {
                self.sample.remove(u, v);
            }
        }
    }

    /// Finalize into count estimates.
    pub fn finish(self) -> GabeEstimate {
        let nv = self.degrees.len() as u64;
        let counts = assemble_counts(nv as f64, self.ne as f64, &self.degrees, &self.c);
        GabeEstimate { counts, nv, ne: self.ne, degrees: self.degrees }
    }
}

/// [`GraphDescriptor`] adapter: shuffle → stream → finalize.
#[derive(Debug, Clone)]
pub struct Gabe {
    pub budget: Budget,
}

impl GraphDescriptor for Gabe {
    fn name(&self) -> String {
        match self.budget {
            Budget::Fraction(f) => format!("GABE@{f}"),
            Budget::Edges(b) => format!("GABE@b={b}"),
            Budget::Exact => "GABE@exact".into(),
        }
    }

    fn dim(&self) -> usize {
        N_GRAPHLETS
    }

    fn compute(&self, g: &Graph, seed: u64) -> Vec<f64> {
        let mut stream = super::stream_of(g, seed);
        let b = super::resolve_budget(self.budget, &stream);
        let est = GabeEstimator::new(b).with_seed(seed ^ 0x6a6e).run(&mut stream);
        est.descriptor().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::count::brute::subgraph_census;
    use crate::count::idx;
    use crate::gen;
    use crate::graph::stream::VecStream;

    /// ISSUE 4: the direct estimator path surfaces mid-stream I/O errors
    /// instead of estimating from a silently truncated prefix.
    #[test]
    fn try_run_fails_on_midstream_error() {
        use crate::graph::stream::{FailAfter, ReaderStream};
        let mut text = String::new();
        for i in 0..40u32 {
            text.push_str(&format!("{} {}\n", i, i + 1));
        }
        let mut s =
            ReaderStream::new(std::io::BufReader::new(FailAfter::new(text.into_bytes(), 80)));
        let err = GabeEstimator::new(100)
            .try_run(&mut s)
            .expect_err("mid-file failure must not yield an estimate");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    /// With b ≥ |E| every weight is 1 and the estimate must be exact.
    #[test]
    fn exact_mode_matches_brute_force() {
        let mut rng = Pcg64::seed_from_u64(5);
        for trial in 0..8 {
            let g = gen::er_graph(14, 30 + trial, &mut rng);
            let want = subgraph_census(&g);
            let mut s = VecStream::shuffled(g.edges.clone(), trial as u64);
            let est = GabeEstimator::new(g.m() + 1).run(&mut s);
            for i in 0..N_GRAPHLETS {
                assert!(
                    (est.counts[i] - want[i]).abs() < 1e-6,
                    "trial {trial} graphlet {i}: {} vs {}",
                    est.counts[i],
                    want[i]
                );
            }
        }
    }

    /// Stream order must not change the exact-mode answer.
    #[test]
    fn exact_mode_order_invariant() {
        let mut rng = Pcg64::seed_from_u64(6);
        let g = gen::powerlaw_cluster_graph(30, 3, 0.6, &mut rng);
        let mut base: Option<[f64; N_GRAPHLETS]> = None;
        for seed in 0..5 {
            let mut s = VecStream::shuffled(g.edges.clone(), seed);
            let est = GabeEstimator::new(g.m()).run(&mut s);
            match &base {
                None => base = Some(est.counts),
                Some(b) => {
                    for i in 0..N_GRAPHLETS {
                        assert!((b[i] - est.counts[i]).abs() < 1e-6, "seed {seed}");
                    }
                }
            }
        }
    }

    /// Theorem 1 (unbiasedness): the estimator mean over many runs must be
    /// close to the true count even with a small budget.
    #[test]
    fn budgeted_estimates_are_unbiased() {
        let mut rng = Pcg64::seed_from_u64(7);
        let g = gen::powerlaw_cluster_graph(60, 4, 0.7, &mut rng);
        let want = subgraph_census(&g);
        let runs = 600;
        let b = g.m() / 2;
        let mut mean = [0.0f64; N_GRAPHLETS];
        for r in 0..runs {
            let mut s = VecStream::shuffled(g.edges.clone(), r);
            let est = GabeEstimator::new(b).with_seed(r ^ 0xdead).run(&mut s);
            for i in 0..N_GRAPHLETS {
                mean[i] += est.counts[i] / runs as f64;
            }
        }
        for i in [idx::TRIANGLE, idx::PATH4, idx::CYCLE4, idx::PAW] {
            let rel = (mean[i] - want[i]).abs() / want[i].max(1.0);
            assert!(rel < 0.08, "graphlet {i}: mean {} vs true {}", mean[i], want[i]);
        }
    }

    #[test]
    fn descriptor_is_normalized_and_finite() {
        let mut rng = Pcg64::seed_from_u64(8);
        let g = gen::er_graph(200, 800, &mut rng);
        let mut s = VecStream::shuffled(g.edges.clone(), 3);
        let est = GabeEstimator::new(200).run(&mut s);
        let d = est.descriptor();
        assert!(d.iter().all(|x| x.is_finite()));
        // φ2 entries: induced edge share ≈ density ∈ (0,1)
        assert!(d[idx::EDGE] > 0.0 && d[idx::EDGE] < 1.0);
        assert!((d[idx::E2] + d[idx::EDGE] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn respects_budget() {
        let mut rng = Pcg64::seed_from_u64(9);
        let g = gen::ba_graph(500, 3, &mut rng);
        let mut s = VecStream::shuffled(g.edges.clone(), 4);
        // run with tiny budget: must not blow up and must see all degrees
        let est = GabeEstimator::new(16).run(&mut s);
        assert_eq!(est.ne as usize, g.m());
        assert_eq!(est.degrees, g.degrees());
    }
}
