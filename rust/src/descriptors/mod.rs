//! The paper's three streaming descriptors (§4) and the SOTA comparators
//! (§5.3).
//!
//! | descriptor | paper basis | passes | module |
//! |------------|-------------------|--------|--------|
//! | GABE       | Graphlet Kernel   | 1      | [`gabe`] |
//! | MAEVE      | NetSimile subset  | 1      | [`maeve`] |
//! | SANTA      | NetLSD (Taylor)   | 2      | [`santa`] |
//! | NetLSD     | full spectrum     | n/a    | [`netlsd`] |
//! | FEATHER    | char. functions   | n/a    | [`feather`] |
//! | SF         | bottom-k spectrum | n/a    | [`sf`] |

pub mod feather;
pub mod gabe;
pub mod maeve;
pub mod netlsd;
pub mod netsimile;
pub mod psi;
pub mod santa;
pub mod sf;

use crate::graph::stream::{EdgeStream, VecStream};
use crate::graph::Graph;

/// How much of the stream a budgeted estimator may store (constraint C2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Budget {
    /// Absolute number of edges.
    Edges(usize),
    /// Fraction of `|E_G|` (the paper's ¼/½ settings).
    Fraction(f64),
    /// Unlimited — the estimator degenerates to the exact algorithm.
    Exact,
}

impl Budget {
    /// Resolve against a stream length.
    pub fn resolve(&self, m: usize) -> usize {
        match *self {
            Budget::Edges(b) => b.max(1),
            Budget::Fraction(f) => ((m as f64 * f).ceil() as usize).max(1),
            Budget::Exact => m.max(1),
        }
    }
}

/// A descriptor that runs on a full in-memory graph (SOTA baselines) or by
/// streaming its shuffled edges (our estimators).  `seed` drives both the
/// stream shuffle and the reservoir.
pub trait GraphDescriptor: Send + Sync {
    /// Display name, including the budget setting (e.g. `GABE@0.25`).
    fn name(&self) -> String;
    /// Descriptor dimensionality.
    fn dim(&self) -> usize;
    /// Compute the descriptor of `g`; `seed` drives the stream shuffle
    /// and the reservoir.
    fn compute(&self, g: &Graph, seed: u64) -> Vec<f64>;
}

/// Helper: shuffled stream for a graph (paper §5.2).
pub fn stream_of(g: &Graph, seed: u64) -> VecStream {
    VecStream::shuffled(g.edges.clone(), seed)
}

/// Helper: resolve a budget against a stream.  The resettable in-tree
/// stream types report a real `len_hint` (`VecStream` trivially;
/// `FileStream` from its open-time count or binary header), so
/// `Budget::Fraction` resolves against the true `|E|`.
///
/// Relative budgets (`Fraction`, `Exact`) over a *hintless* stream
/// (`ReaderStream` et al.) are an error: a fraction of an unknown `|E|` is
/// not computable in one pass, and the old `1 << 20` fallback silently
/// turned "¼ of the stream" into "up to a million edges" — wrong in both
/// directions (ISSUE 6 bugfix).  Use `Budget::Edges` for one-shot sources,
/// or convert the input to the binary format (`repro convert`), whose
/// header carries `|E|`.
pub fn resolve_budget(b: Budget, s: &impl EdgeStream) -> crate::Result<usize> {
    match (s.len_hint(), b) {
        (Some(m), _) => Ok(b.resolve(m)),
        (None, Budget::Edges(n)) => Ok(n.max(1)),
        (None, Budget::Fraction(f)) => Err(crate::anyhow!(
            "Budget::Fraction({f}) needs a stream length hint, but this stream \
             reports none; use Budget::Edges or a FileStream/binary input"
        )),
        (None, Budget::Exact) => Err(crate::anyhow!(
            "Budget::Exact needs a stream length hint, but this stream reports \
             none; use Budget::Edges or a FileStream/binary input"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_resolution() {
        assert_eq!(Budget::Edges(10).resolve(100), 10);
        assert_eq!(Budget::Fraction(0.25).resolve(100), 25);
        assert_eq!(Budget::Fraction(0.5).resolve(101), 51);
        assert_eq!(Budget::Exact.resolve(100), 100);
        assert_eq!(Budget::Edges(0).resolve(100), 1);
    }

    /// ISSUE 6 regression: a relative budget over a hintless stream errors
    /// instead of resolving against the old fabricated `1 << 20` length.
    #[test]
    fn relative_budget_over_hintless_stream_errors() {
        use crate::graph::stream::ReaderStream;
        let mk = || ReaderStream::new(std::io::BufReader::new(std::io::Cursor::new(b"0 1\n")));
        let err = resolve_budget(Budget::Fraction(0.25), &mk()).unwrap_err();
        assert!(err.to_string().contains("length hint"), "{err}");
        let err = resolve_budget(Budget::Exact, &mk()).unwrap_err();
        assert!(err.to_string().contains("length hint"), "{err}");
        // absolute budgets never need the hint
        assert_eq!(resolve_budget(Budget::Edges(7), &mk()).unwrap(), 7);
        // and a hinted stream resolves as before
        let v = VecStream::new((0..40).map(|i| crate::graph::Edge::new(i, i + 1)).collect());
        assert_eq!(resolve_budget(Budget::Fraction(0.25), &v).unwrap(), 10);
    }
}
