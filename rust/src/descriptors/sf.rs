//! SF (de Lara & Pineau, 2018) — the "simple baseline": the bottom-k
//! eigenvalues of the normalized Laplacian as the embedding (§5.3).
//!
//! The paper sets the embedding dimension to the dataset's average order;
//! we cap it so dense solves stay tractable and zero-pad smaller graphs
//! (the reference implementation does the same).

use crate::util::rng::Pcg64;

use super::GraphDescriptor;
use crate::graph::csr::Csr;
use crate::graph::Graph;
use crate::linalg::lanczos::lanczos_ritz_values;
use crate::linalg::symmetric_eigenvalues;

/// SF baseline descriptor.
#[derive(Debug, Clone)]
pub struct Sf {
    /// Embedding dimension (bottom-k eigenvalues, zero-padded).
    pub k: usize,
    /// Dense eigensolve cutoff; Lanczos beyond.
    pub dense_cutoff: usize,
}

impl Sf {
    /// SF with the `k` smallest eigenvalues.
    pub fn new(k: usize) -> Self {
        Sf { k: k.max(1), dense_cutoff: 1024 }
    }

    /// Dimension from a dataset's average order (paper's suggestion),
    /// capped at 128.
    pub fn for_dataset(avg_order: f64) -> Self {
        Self::new((avg_order.round() as usize).clamp(4, 128))
    }

    /// The k smallest normalized-Laplacian eigenvalues of `g`, ascending.
    pub fn descriptor(&self, g: &Graph, seed: u64) -> Vec<f64> {
        let csr = Csr::from_graph(g);
        let eigs = if g.n <= self.dense_cutoff {
            symmetric_eigenvalues(&csr.normalized_laplacian(), g.n)
        } else {
            let mut rng = Pcg64::seed_from_u64(seed ^ 0x5f);
            lanczos_ritz_values(
                g.n,
                |x, y| csr.laplacian_matvec(x, y),
                (4 * self.k).min(g.n),
                &mut rng,
            )
        };
        let mut out = vec![0.0; self.k];
        for (i, v) in eigs.iter().take(self.k).enumerate() {
            out[i] = *v;
        }
        out
    }
}

impl GraphDescriptor for Sf {
    fn name(&self) -> String {
        format!("SF-k{}", self.k)
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn compute(&self, g: &Graph, seed: u64) -> Vec<f64> {
        self.descriptor(g, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_with_zeros() {
        let g = Graph::from_pairs([(0, 1), (1, 2)]);
        let d = Sf::new(8).descriptor(&g, 0);
        assert_eq!(d.len(), 8);
        assert!(d[0].abs() < 1e-12); // λ₁ = 0
        assert_eq!(&d[3..], &[0.0; 5]);
    }

    #[test]
    fn connected_components_show_as_zeros() {
        let g = Graph::from_pairs([(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)]);
        let d = Sf::new(4).descriptor(&g, 0);
        assert!(d[0].abs() < 1e-10 && d[1].abs() < 1e-10);
        assert!(d[2] > 0.5);
    }

    #[test]
    fn for_dataset_clamps() {
        assert_eq!(Sf::for_dataset(3000.0).k, 128);
        assert_eq!(Sf::for_dataset(1.0).k, 4);
    }
}
