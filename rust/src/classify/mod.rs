//! k-NN graph classification with repeated k-fold cross-validation (§6.2).
//!
//! The paper uses a nearest-neighbor classifier, 10-fold CV repeated over
//! 10 random splits, reporting mean fold accuracy.  Distances: Canberra
//! for GABE/MAEVE, ℓ₂ for spectral descriptors (§5.1).  When the PJRT
//! runtime is available the distance matrix comes from the L2
//! `pairwise_dist` artifact; [`DistanceMatrix`] is the backend-agnostic
//! consumer.

use crate::util::rng::Pcg64;

use crate::analyze::{canberra, euclidean};

/// Distance used to compare descriptor vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Canberra distance — Σ |aᵢ−bᵢ| / (|aᵢ|+|bᵢ|) (GABE/MAEVE, §5.1).
    Canberra,
    /// Euclidean (ℓ₂) distance (spectral descriptors, §5.1).
    Euclidean,
}

/// Dense symmetric distance matrix.
#[derive(Debug, Clone)]
pub struct DistanceMatrix {
    /// Number of items (the matrix is `n × n`).
    pub n: usize,
    /// Row-major distances; `d[i*n + j]` is the distance between `i`/`j`.
    pub d: Vec<f64>,
}

impl DistanceMatrix {
    /// Compute on the CPU (rust fallback / test oracle for the L2 kernel).
    pub fn compute(descriptors: &[Vec<f64>], metric: Metric) -> Self {
        let n = descriptors.len();
        let mut d = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i + 1..n {
                let v = match metric {
                    Metric::Canberra => canberra(&descriptors[i], &descriptors[j]),
                    Metric::Euclidean => euclidean(&descriptors[i], &descriptors[j]),
                };
                d[i * n + j] = v;
                d[j * n + i] = v;
            }
        }
        DistanceMatrix { n, d }
    }

    /// Wrap an externally computed (e.g. PJRT) matrix.
    pub fn from_raw(n: usize, d: Vec<f64>) -> Self {
        assert_eq!(d.len(), n * n);
        DistanceMatrix { n, d }
    }

    /// Distance between items `i` and `j`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.d[i * self.n + j]
    }
}

/// 1-NN prediction for `test` items against `train` indices.
fn knn_predict(dm: &DistanceMatrix, labels: &[usize], train: &[usize], item: usize) -> usize {
    let mut best = f64::INFINITY;
    let mut lab = 0;
    for &t in train {
        let d = dm.get(item, t);
        if d < best {
            best = d;
            lab = labels[t];
        }
    }
    lab
}

/// Result of a cross-validation run.
#[derive(Debug, Clone)]
pub struct CvResult {
    /// Mean fold accuracy in percent.
    pub accuracy: f64,
    /// Std dev of fold accuracies.
    pub std: f64,
    /// Folds per repeat (after clamping to the item count).
    pub folds: usize,
    /// Independent shuffled repeats.
    pub repeats: usize,
}

/// `repeats` × `folds`-fold CV of a 1-NN classifier over a precomputed
/// distance matrix (paper §6.2: 10 × 10).
pub fn cross_validate(
    dm: &DistanceMatrix,
    labels: &[usize],
    folds: usize,
    repeats: usize,
    seed: u64,
) -> CvResult {
    assert_eq!(dm.n, labels.len());
    let n = dm.n;
    let folds = folds.min(n).max(2);
    let mut accs: Vec<f64> = Vec::with_capacity(folds * repeats);
    for rep in 0..repeats {
        let mut order: Vec<usize> = (0..n).collect();
        Pcg64::seed_from_u64(seed ^ (rep as u64) << 17).shuffle(&mut order);
        for f in 0..folds {
            let test: Vec<usize> =
                order.iter().copied().skip(f).step_by(folds).collect();
            let train: Vec<usize> = order
                .iter()
                .copied()
                .enumerate()
                .filter(|(i, _)| i % folds != f)
                .map(|(_, v)| v)
                .collect();
            if test.is_empty() || train.is_empty() {
                continue;
            }
            let correct = test
                .iter()
                .filter(|&&i| knn_predict(dm, labels, &train, i) == labels[i])
                .count();
            accs.push(correct as f64 / test.len() as f64 * 100.0);
        }
    }
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    let var = accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>()
        / accs.len() as f64;
    CvResult { accuracy: mean, std: var.sqrt(), folds, repeats }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(n_per: usize, sep: f64, seed: u64) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for c in 0..2 {
            for _ in 0..n_per {
                x.push(vec![
                    c as f64 * sep + rng.gen_range_f64(-1.0, 1.0),
                    rng.gen_range_f64(-1.0, 1.0),
                ]);
                y.push(c);
            }
        }
        (x, y)
    }

    #[test]
    fn distance_matrix_symmetric_zero_diag() {
        let (x, _) = blobs(10, 3.0, 1);
        let dm = DistanceMatrix::compute(&x, Metric::Euclidean);
        for i in 0..dm.n {
            assert_eq!(dm.get(i, i), 0.0);
            for j in 0..dm.n {
                assert_eq!(dm.get(i, j), dm.get(j, i));
            }
        }
    }

    #[test]
    fn separable_blobs_classify_perfectly() {
        let (x, y) = blobs(30, 20.0, 2);
        let dm = DistanceMatrix::compute(&x, Metric::Euclidean);
        let r = cross_validate(&dm, &y, 10, 3, 7);
        assert!(r.accuracy > 99.0, "accuracy {}", r.accuracy);
    }

    #[test]
    fn random_labels_near_chance() {
        let mut rng = Pcg64::seed_from_u64(3);
        let x: Vec<Vec<f64>> =
            (0..200).map(|_| vec![rng.gen_range_f64(-1.0, 1.0); 4]).collect();
        let y: Vec<usize> = (0..200).map(|_| rng.gen_range_usize(0, 2)).collect();
        let dm = DistanceMatrix::compute(&x, Metric::Euclidean);
        let r = cross_validate(&dm, &y, 10, 3, 8);
        assert!(r.accuracy > 30.0 && r.accuracy < 70.0, "accuracy {}", r.accuracy);
    }

    #[test]
    fn canberra_metric_used() {
        let x = vec![vec![1.0, 0.0], vec![3.0, 0.0]];
        let dm = DistanceMatrix::compute(&x, Metric::Canberra);
        assert!((dm.get(0, 1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cv_deterministic() {
        let (x, y) = blobs(20, 5.0, 4);
        let dm = DistanceMatrix::compute(&x, Metric::Euclidean);
        let a = cross_validate(&dm, &y, 5, 2, 11);
        let b = cross_validate(&dm, &y, 5, 2, 11);
        assert_eq!(a.accuracy, b.accuracy);
    }
}
