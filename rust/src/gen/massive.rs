//! KONECT-like massive networks (paper Table 13, §6.3 scalability runs).
//!
//! Type-matched synthetic stand-ins for the seven KONECT graphs; `scale`
//! multiplies the default sizes (which are reduced from the paper's so the
//! harness finishes on one machine — the *shape* of Tables 16/17 is what we
//! reproduce).

use std::path::{Path, PathBuf};

use crate::util::rng::Pcg64;

use super::{ba_graph, community_graph, powerlaw_cluster_graph, road_graph};
use crate::graph::ingest::write_binary_edge_list;
use crate::graph::stream::write_edge_list;
use crate::graph::Graph;

/// The seven network types of Table 13.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MassiveKind {
    /// Florida road network (road: grid-like, tiny degree).
    Fo,
    /// USA road network (road, larger).
    Us,
    /// CiteSeer citations (citation: BA-like).
    Cs,
    /// Patent citations (citation, larger).
    Pt,
    /// Flickr friendships (social: heavy tail + clustering).
    Fl,
    /// Stanford hyperlinks (hyperlink: dense communities).
    Sf,
    /// UK-2002 hyperlinks (hyperlink, largest).
    U2,
}

impl MassiveKind {
    /// Every network, in Table 13 order.
    pub const ALL: [MassiveKind; 7] = [
        MassiveKind::Fo,
        MassiveKind::Us,
        MassiveKind::Cs,
        MassiveKind::Pt,
        MassiveKind::Fl,
        MassiveKind::Sf,
        MassiveKind::U2,
    ];

    /// The paper's two-letter network tag (also the `--net` CLI spelling).
    pub fn name(&self) -> &'static str {
        match self {
            MassiveKind::Fo => "FO",
            MassiveKind::Us => "US",
            MassiveKind::Cs => "CS",
            MassiveKind::Pt => "PT",
            MassiveKind::Fl => "FL",
            MassiveKind::Sf => "SF",
            MassiveKind::U2 => "U2",
        }
    }

    /// Paper-reported |V|, |E| (Table 13) — for the scale-factor note in
    /// experiment output.
    pub fn paper_size(&self) -> (u64, u64) {
        match self {
            MassiveKind::Fo => (1_070_376, 1_343_951),
            MassiveKind::Us => (23_947_347, 28_854_312),
            MassiveKind::Cs => (384_054, 1_736_145),
            MassiveKind::Pt => (3_774_768, 16_518_937),
            MassiveKind::Fl => (2_302_925, 22_838_276),
            MassiveKind::Sf => (281_903, 1_992_636),
            MassiveKind::U2 => (18_483_186, 261_787_258),
        }
    }
}

impl std::str::FromStr for MassiveKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        MassiveKind::ALL
            .iter()
            .find(|k| k.name().eq_ignore_ascii_case(s))
            .copied()
            .ok_or_else(|| format!("unknown network {s} (want FO/US/CS/PT/FL/SF/U2)"))
    }
}

/// Generate the stand-in network. Default sizes are ~1/10 of the paper's
/// (U2 ~1/40) so the full Table 16/17 harness completes locally.
pub fn massive_graph(kind: MassiveKind, scale: f64, seed: u64) -> Graph {
    let mut rng = Pcg64::seed_from_u64(seed ^ (kind as u64) << 32);
    let s = scale.max(1e-3);
    match kind {
        MassiveKind::Fo => road_graph(((330.0 * s.sqrt()) as usize).max(10), &mut rng),
        MassiveKind::Us => road_graph(((1550.0 * s.sqrt()) as usize).max(10), &mut rng),
        MassiveKind::Cs => ba_graph(((40_000.0 * s) as usize).max(16), 4, &mut rng),
        MassiveKind::Pt => ba_graph(((380_000.0 * s) as usize).max(16), 4, &mut rng),
        MassiveKind::Fl => {
            powerlaw_cluster_graph(((230_000.0 * s) as usize).max(20), 9, 0.35, &mut rng)
        }
        MassiveKind::Sf => {
            let n = ((28_000.0 * s) as usize).max(40);
            community_graph(n, (n / 2000).max(2), n * 6, n, &mut rng)
        }
        MassiveKind::U2 => {
            let n = ((450_000.0 * s) as usize).max(40);
            community_graph(n, (n / 10_000).max(2), n * 12, n, &mut rng)
        }
    }
}

/// Paths of one on-disk stream fixture: the same shuffled edge order in
/// both encodings.
#[derive(Debug, Clone)]
pub struct StreamFixture {
    /// Text edge list (`u v` lines).
    pub text: PathBuf,
    /// Binary edge list (`.sdg`, ISSUE 6 format).
    pub binary: PathBuf,
    /// Edges in each file.
    pub edges: usize,
}

/// Write one massive-network stand-in to `dir` as a *stream fixture*: the
/// paper-shuffled edge order (§5.2) serialized as both a text edge list
/// and its binary `.sdg` twin, so ingest benches and differential tests
/// can read the identical stream through either decoder.
pub fn write_stream_fixture(
    kind: MassiveKind,
    scale: f64,
    seed: u64,
    dir: impl AsRef<Path>,
) -> crate::Result<StreamFixture> {
    let g = massive_graph(kind, scale, seed);
    let stream = crate::graph::stream::VecStream::shuffled(g.edges, seed);
    let edges = stream.edges();
    let base = format!("{}-s{scale}", kind.name().to_ascii_lowercase());
    let text = dir.as_ref().join(format!("{base}.txt"));
    let binary = dir.as_ref().join(format!("{base}.sdg"));
    write_edge_list(&text, edges)?;
    write_binary_edge_list(&binary, g.n as u64, edges)?;
    Ok(StreamFixture { text, binary, edges: edges.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_generate_small() {
        for kind in MassiveKind::ALL {
            let g = massive_graph(kind, 0.01, 1);
            assert!(g.m() > 50, "{:?}: m = {}", kind, g.m());
        }
    }

    #[test]
    fn road_vs_social_density() {
        let road = massive_graph(MassiveKind::Fo, 0.05, 2);
        let social = massive_graph(MassiveKind::Fl, 0.05, 2);
        assert!(road.avg_degree() < 5.0);
        assert!(social.avg_degree() > 8.0);
    }

    /// ISSUE 6: both encodings of a fixture replay the identical stream.
    #[test]
    fn stream_fixture_encodings_agree() {
        use crate::graph::stream::{EdgeStream, FileStream};
        let dir = crate::util::tmp::TempDir::new("fixture").unwrap();
        let fx = write_stream_fixture(MassiveKind::Cs, 0.01, 3, dir.path()).unwrap();
        assert!(fx.edges > 50);
        let drain = |p: &std::path::Path| {
            let mut s = FileStream::open(p).unwrap();
            assert_eq!(s.len_hint(), Some(fx.edges), "{}", p.display());
            let mut v = Vec::new();
            while s.next_batch(&mut v, 1024) > 0 {}
            assert!(s.take_error().is_none());
            v
        };
        assert_eq!(drain(&fx.text), drain(&fx.binary));
    }

    #[test]
    fn deterministic() {
        let a = massive_graph(MassiveKind::Cs, 0.01, 5);
        let b = massive_graph(MassiveKind::Cs, 0.01, 5);
        assert_eq!(a.edges.len(), b.edges.len());
        assert_eq!(a.edges[..10], b.edges[..10]);
    }
}
