//! Class-structured classification datasets standing in for the paper's
//! TUDataset benchmarks (Table 12).
//!
//! Each dataset is a list of `(Graph, label)` pairs whose classes come from
//! *distinct generator families / parameter bands*, giving the k-NN
//! classifier genuine structure to find (DESIGN.md §3).  Graph counts and
//! order/size bands mirror Table 12, scaled by `scale` so CI runs stay
//! cheap (`scale = 1.0` reproduces the paper's magnitudes).

use crate::util::rng::Pcg64;

use super::{ba_graph, community_graph, er_graph, powerlaw_cluster_graph, ws_graph};
use crate::graph::Graph;

/// A labelled classification dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Table 12 dataset name (one of [`SPECS`]).
    pub name: String,
    /// The graphs, class-interleaved (`graphs[i]` has `labels[i]`).
    pub graphs: Vec<Graph>,
    /// Class label per graph, in `0..n_classes`.
    pub labels: Vec<usize>,
    /// Number of distinct classes.
    pub n_classes: usize,
}

impl Dataset {
    /// Number of graphs.
    pub fn len(&self) -> usize {
        self.graphs.len()
    }
    /// True when the dataset holds no graphs.
    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }
    /// Largest graph order `|V|` (Table 12's "Max. Order" column).
    pub fn max_order(&self) -> usize {
        self.graphs.iter().map(|g| g.n).max().unwrap_or(0)
    }
    /// Largest graph size `|E|` (Table 12's "Max. Size" column).
    pub fn max_size(&self) -> usize {
        self.graphs.iter().map(|g| g.m()).max().unwrap_or(0)
    }
}

/// Table 12 stand-in specs: (name, #graphs, #classes).
pub const SPECS: [(&str, usize, usize); 8] = [
    ("FMM", 41, 11),
    ("OHSU", 79, 2),
    ("DD", 1178, 2),
    ("RDT2", 2000, 2),
    ("RDT5", 4999, 5),
    ("CLB", 5000, 3),
    ("RDT12", 11929, 11),
    ("GHUB", 12725, 2),
];

/// Generate one graph for (dataset, class) with per-class parameter bands.
fn class_graph(name: &str, class: usize, rng: &mut Pcg64) -> Graph {
    match name {
        // protein-like (DD): medium sparse graphs; classes differ in
        // clustering (lattice-ish vs random).
        "DD" => {
            let n = rng.gen_range_usize(60, 800);
            if class == 0 {
                ws_graph(n.max(12), 6, 0.15, rng)
            } else {
                er_graph(n.max(12), (n as f64 * 2.4) as usize, rng)
            }
        }
        // reddit-binary-like: sparse trees-with-hubs; classes differ in
        // hub dominance (Q&A threads vs discussions).
        "RDT2" => {
            let n = rng.gen_range_usize(80, 2500);
            let m = if class == 0 { 1 } else { 2 };
            ba_graph(n.max(8), m, rng)
        }
        // reddit-5/12: star-vs-community mixtures per class band.
        "RDT5" | "RDT12" => {
            let n = rng.gen_range_usize(100, 2200);
            let k = 2 + class % 4;
            let din = 1.0 + 0.5 * (class as f64 / 2.0);
            let m_in = (n as f64 * din) as usize;
            community_graph(n.max(4 * k), k, m_in, m_in / 8 + 1, rng)
        }
        // collab-like (CLB): dense ego-networks; classes = density bands.
        "CLB" => {
            let n = rng.gen_range_usize(40, 400);
            let m = [4usize, 8, 16][class % 3].min(n / 2 - 1).max(1);
            powerlaw_cluster_graph(n.max(2 * m + 2), m, 0.7, rng)
        }
        // brain-network-like (OHSU): small, two density regimes.
        "OHSU" => {
            let n = rng.gen_range_usize(30, 170);
            let dens = if class == 0 { 2.0 } else { 3.2 };
            er_graph(n, (n as f64 * dens) as usize, rng)
        }
        // github-stargazer-like: bipartite-ish sparse vs clustered.
        "GHUB" => {
            let n = rng.gen_range_usize(40, 950);
            if class == 0 {
                ba_graph(n.max(6), 1, rng)
            } else {
                powerlaw_cluster_graph(n.max(8), 2, 0.5, rng)
            }
        }
        // robot-motion-like (FMM): 11 classes, tiny set; vary family+params.
        "FMM" => {
            let n = rng.gen_range_usize(200, 4000);
            match class % 4 {
                0 => ws_graph(n.max(12), 4 + 2 * (class / 4), 0.1, rng),
                1 => ba_graph(n.max(8), 1 + class / 4, rng),
                2 => er_graph(n, n * (2 + class / 4), rng),
                _ => powerlaw_cluster_graph(n.max(10), 2 + class / 4, 0.4, rng),
            }
        }
        // repro-lint: allow(panic-hygiene): reachable only through a name
        // absent from SPECS — a caller bug, aborted loudly by design.
        other => panic!("unknown dataset {other}"),
    }
}

/// Build a Table 12 stand-in dataset. `scale ∈ (0, 1]` shrinks the graph
/// *count* (class balance preserved); graph sizes are unaffected.
pub fn make_dataset(name: &str, scale: f64, seed: u64) -> Dataset {
    let (_, total, n_classes) = SPECS
        .iter()
        .find(|(n, _, _)| *n == name)
        .copied()
        // repro-lint: allow(panic-hygiene): unknown dataset names are a
        // caller bug (the CLI validates first), aborted loudly by design.
        .unwrap_or_else(|| panic!("unknown dataset {name}"));
    let count = ((total as f64 * scale).round() as usize).max(n_classes * 4);
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x5eed_d474);
    let mut graphs = Vec::with_capacity(count);
    let mut labels = Vec::with_capacity(count);
    for i in 0..count {
        let class = i % n_classes;
        graphs.push(class_graph(name, class, &mut rng));
        labels.push(class);
    }
    Dataset { name: name.to_string(), graphs, labels, n_classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_specs_generate() {
        for (name, _, classes) in SPECS {
            let d = make_dataset(name, 0.02, 7);
            assert!(d.len() >= classes * 4, "{name}");
            assert_eq!(d.n_classes, classes);
            assert!(d.graphs.iter().all(|g| g.m() > 0), "{name}");
            // labels cover all classes
            let mut seen = vec![false; classes];
            for &l in &d.labels {
                seen[l] = true;
            }
            assert!(seen.iter().all(|&s| s), "{name}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = make_dataset("OHSU", 0.5, 3);
        let b = make_dataset("OHSU", 0.5, 3);
        assert_eq!(a.graphs[0].edges, b.graphs[0].edges);
        let c = make_dataset("OHSU", 0.5, 4);
        assert_ne!(a.graphs[0].edges, c.graphs[0].edges);
    }

    #[test]
    fn dd_classes_differ_in_clustering() {
        use crate::graph::csr::Csr;
        let d = make_dataset("DD", 0.05, 11);
        let mut tri = [0.0f64; 2];
        let mut cnt = [0usize; 2];
        for (g, &l) in d.graphs.iter().zip(&d.labels) {
            tri[l] += Csr::from_graph(g).triangle_count() as f64 / g.n as f64;
            cnt[l] += 1;
        }
        let a = tri[0] / cnt[0] as f64;
        let b = tri[1] / cnt[1] as f64;
        assert!(a > b * 1.5, "WS class should have far more triangles: {a} vs {b}");
    }
}
