//! Synthetic graph substrate (DESIGN.md §3 substitutions).
//!
//! The paper evaluates on REDDIT samples, eight TUDataset collections and
//! seven KONECT networks — none redistributable here.  This module builds
//! type-matched synthetic equivalents: random-graph families whose degree
//! shape, density and community structure exercise the same code paths and
//! preserve the experiments' qualitative behaviour (error ↓ with budget ↑,
//! class separability, wall-clock scaling).
//!
//! All generators are deterministic given the seed (Pcg64).

pub mod datasets;
pub mod massive;

use std::collections::HashSet;

use crate::util::rng::Pcg64;

use crate::graph::{Edge, Graph, VertexId};

/// Erdős–Rényi `G(n, m)`: `m` distinct uniform non-loop edges.
pub fn er_graph(n: usize, m: usize, rng: &mut Pcg64) -> Graph {
    assert!(n >= 2);
    let max_m = n * (n - 1) / 2;
    let m = m.min(max_m);
    let mut seen = HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let a = rng.gen_range_u32(0, n as VertexId);
        let b = rng.gen_range_u32(0, n as VertexId);
        if a == b {
            continue;
        }
        let e = Edge::new(a, b);
        if seen.insert(e) {
            edges.push(e);
        }
    }
    Graph::from_edges(n, edges)
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `m_attach` existing vertices chosen ∝ degree (repeated-endpoint trick).
pub fn ba_graph(n: usize, m_attach: usize, rng: &mut Pcg64) -> Graph {
    assert!(n > m_attach && m_attach >= 1);
    let mut endpoints: Vec<VertexId> = Vec::with_capacity(2 * n * m_attach);
    let mut edges: Vec<Edge> = Vec::with_capacity(n * m_attach);
    // seed clique-ish core
    for v in 1..=m_attach as VertexId {
        edges.push(Edge::new(0, v));
        endpoints.extend([0, v]);
    }
    for v in (m_attach + 1) as VertexId..n as VertexId {
        let mut targets: Vec<VertexId> = Vec::with_capacity(m_attach);
        while targets.len() < m_attach {
            let t = endpoints[rng.gen_range_usize(0, endpoints.len())];
            if t != v && !targets.contains(&t) {
                targets.push(t);
            }
        }
        for t in targets {
            edges.push(Edge::new(v, t));
            endpoints.extend([v, t]);
        }
    }
    Graph::from_edges(n, edges)
}

/// Watts–Strogatz small world: ring of degree `k` (even), rewired w.p. `beta`.
pub fn ws_graph(n: usize, k: usize, beta: f64, rng: &mut Pcg64) -> Graph {
    assert!(k % 2 == 0 && k < n && n >= 4);
    let mut seen: HashSet<Edge> = HashSet::new();
    let mut ring: Vec<Edge> = Vec::with_capacity(n * k / 2);
    for v in 0..n {
        for d in 1..=k / 2 {
            let e = Edge::new(v as VertexId, ((v + d) % n) as VertexId);
            if seen.insert(e) {
                ring.push(e);
            }
        }
    }
    for e in ring {
        if rng.gen_bool(beta) {
            // rewire the far endpoint
            for _ in 0..16 {
                let w = rng.gen_range_u32(0, n as VertexId);
                if w != e.u && w != e.v {
                    let ne = Edge::new(e.u, w);
                    if !seen.contains(&ne) {
                        seen.remove(&e);
                        seen.insert(ne);
                        break;
                    }
                }
            }
        }
    }
    Graph::from_edges(n, seen.into_iter().collect())
}

/// Holme–Kim power-law cluster graph: BA with triad-closure probability `p`.
/// Produces the heavy-tailed, high-clustering graphs social datasets show.
pub fn powerlaw_cluster_graph(
    n: usize,
    m_attach: usize,
    p: f64,
    rng: &mut Pcg64,
) -> Graph {
    assert!(n > m_attach && m_attach >= 1);
    let mut endpoints: Vec<VertexId> = Vec::new();
    let mut edges: HashSet<Edge> = HashSet::new();
    let mut nbrs: Vec<Vec<VertexId>> = vec![Vec::new(); n];
    for v in 1..=m_attach as VertexId {
        edges.insert(Edge::new(0, v));
        endpoints.extend([0, v]);
        nbrs[0].push(v);
        nbrs[v as usize].push(0);
    }
    for v in (m_attach + 1) as VertexId..n as VertexId {
        let mut added: Vec<VertexId> = Vec::with_capacity(m_attach);
        while added.len() < m_attach {
            let candidate = if !added.is_empty() && rng.gen_bool(p) {
                // triad closure: neighbor of a previously-linked vertex
                let anchor = added[rng.gen_range_usize(0, added.len())];
                let anbrs = &nbrs[anchor as usize];
                anbrs[rng.gen_range_usize(0, anbrs.len())]
            } else {
                endpoints[rng.gen_range_usize(0, endpoints.len())]
            };
            if candidate == v || added.contains(&candidate) {
                continue;
            }
            let e = Edge::new(v, candidate);
            if edges.insert(e) {
                added.push(candidate);
                endpoints.extend([v, candidate]);
                nbrs[v as usize].push(candidate);
                nbrs[candidate as usize].push(v);
            }
        }
    }
    Graph::from_edges(n, edges.into_iter().collect())
}

/// Planted-partition community graph: `k` equal communities, `m_in` edges
/// inside communities, `m_out` across — REDDIT-thread-like structure.
pub fn community_graph(
    n: usize,
    k: usize,
    m_in: usize,
    m_out: usize,
    rng: &mut Pcg64,
) -> Graph {
    assert!(k >= 1 && n >= 2 * k);
    let csize = n / k;
    let mut seen = HashSet::with_capacity((m_in + m_out) * 2);
    let mut edges = Vec::with_capacity(m_in + m_out);
    let mut tries = 0usize;
    while edges.len() < m_in && tries < m_in * 50 {
        tries += 1;
        let c = rng.gen_range_usize(0, k);
        let base = (c * csize) as VertexId;
        let hi = if c == k - 1 { n } else { (c + 1) * csize } as VertexId;
        let a = rng.gen_range_u32(base, hi);
        let b = rng.gen_range_u32(base, hi);
        if a == b {
            continue;
        }
        let e = Edge::new(a, b);
        if seen.insert(e) {
            edges.push(e);
        }
    }
    tries = 0;
    let target = edges.len() + m_out;
    while edges.len() < target && tries < m_out * 50 {
        tries += 1;
        let a = rng.gen_range_u32(0, n as VertexId);
        let b = rng.gen_range_u32(0, n as VertexId);
        if a == b || (a as usize / csize).min(k - 1) == (b as usize / csize).min(k - 1)
        {
            continue;
        }
        let e = Edge::new(a, b);
        if seen.insert(e) {
            edges.push(e);
        }
    }
    Graph::from_edges(n, edges)
}

/// Road-network-like graph: 2D grid with Poisson-perturbed deletions and a
/// few diagonal shortcuts (low, near-constant degree; huge diameter).
pub fn road_graph(side: usize, rng: &mut Pcg64) -> Graph {
    let n = side * side;
    let id = |r: usize, c: usize| (r * side + c) as VertexId;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            if c + 1 < side && rng.gen_bool(0.95) {
                edges.push(Edge::new(id(r, c), id(r, c + 1)));
            }
            if r + 1 < side && rng.gen_bool(0.95) {
                edges.push(Edge::new(id(r, c), id(r + 1, c)));
            }
            if r + 1 < side && c + 1 < side && rng.gen_bool(0.03) {
                edges.push(Edge::new(id(r, c), id(r + 1, c + 1)));
            }
        }
    }
    Graph::from_edges(n, edges)
}

/// REDDIT-like interaction graph (paper §6.1): community structure over a
/// heavy-tailed degree profile, sized to land in the paper's 10k–50k-edge
/// band.
pub fn reddit_like(rng: &mut Pcg64) -> Graph {
    let m_target = rng.gen_range_usize(10_000, 50_001);
    let n = (m_target as f64 / rng.gen_range_f64(1.8, 3.2)) as usize;
    let k = rng.gen_range_usize(4, 12);
    let m_in = (m_target as f64 * 0.8) as usize;
    let m_out = m_target - m_in;
    let base = community_graph(n.max(2 * k), k, m_in, m_out, rng);
    // splice in a few hubs (poisson bursts) for the heavy tail
    let mut edges = base.edges;
    let hubs = rng.gen_range_usize(3, 10);
    let lambda = (m_target as f64 * 0.01).max(2.0);
    let mut seen: HashSet<Edge> = edges.iter().copied().collect();
    for _ in 0..hubs {
        let h = rng.gen_range_u32(0, base.n as VertexId);
        let burst = rng.poisson(lambda) as usize;
        for _ in 0..burst {
            let t = rng.gen_range_u32(0, base.n as VertexId);
            if t != h {
                let e = Edge::new(h, t);
                if seen.insert(e) {
                    edges.push(e);
                }
            }
        }
    }
    Graph::from_edges(base.n, edges)
}

/// Churned edge stream for the drift workload (ISSUE 5): each phase's
/// edges are shuffled independently (so arrivals inside a phase are
/// unbiased, §5.2), then the phases are concatenated *in order* — the
/// stream's structure changes regime at each phase boundary instead of
/// being stationary.  Feed it to a windowed estimator to watch the
/// descriptor time series drift from one regime to the next.
pub fn churned_stream(phases: &[&Graph], seed: u64) -> Vec<Edge> {
    let mut out = Vec::with_capacity(phases.iter().map(|g| g.m()).sum());
    for (i, g) in phases.iter().enumerate() {
        let mut edges = g.edges.clone();
        Pcg64::seed_from_u64(seed ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
            .shuffle(&mut edges);
        out.extend_from_slice(&edges);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rng(seed: u64) -> Pcg64 {
        Pcg64::seed_from_u64(seed)
    }

    #[test]
    fn churned_stream_keeps_phases_in_order() {
        let a = er_graph(30, 60, &mut rng(10));
        let b = ba_graph(30, 2, &mut rng(11));
        let s = churned_stream(&[&a, &b], 5);
        assert_eq!(s.len(), a.m() + b.m());
        let mut head = s[..a.m()].to_vec();
        head.sort_unstable();
        assert_eq!(head, a.edges, "phase 1 is a permutation of graph A");
        let mut tail = s[a.m()..].to_vec();
        tail.sort_unstable();
        assert_eq!(tail, b.edges, "phase 2 is a permutation of graph B");
        // deterministic given the seed
        assert_eq!(s, churned_stream(&[&a, &b], 5));
        assert_ne!(s, churned_stream(&[&a, &b], 6));
    }

    #[test]
    fn er_exact_edge_count_and_simple() {
        let g = er_graph(100, 300, &mut rng(1));
        assert_eq!(g.m(), 300);
        assert_eq!(g.n, 100);
        let mut e = g.edges.clone();
        e.sort_unstable();
        e.dedup();
        assert_eq!(e.len(), 300);
    }

    #[test]
    fn er_caps_at_complete_graph() {
        let g = er_graph(5, 100, &mut rng(2));
        assert_eq!(g.m(), 10);
    }

    #[test]
    fn ba_size_and_heavy_tail() {
        let g = ba_graph(2000, 3, &mut rng(3));
        assert_eq!(g.m(), 3 + (2000 - 4) * 3);
        let deg = g.degrees();
        let dmax = *deg.iter().max().unwrap();
        assert!(dmax > 30, "BA should grow hubs, max degree {dmax}");
    }

    #[test]
    fn ws_keeps_edge_count_close() {
        let g = ws_graph(500, 6, 0.1, &mut rng(4));
        assert!(g.m() >= 1400 && g.m() <= 1500, "m = {}", g.m());
    }

    #[test]
    fn powerlaw_cluster_has_more_triangles_than_ba() {
        use crate::graph::csr::Csr;
        let hk = powerlaw_cluster_graph(1500, 3, 0.8, &mut rng(5));
        let ba = ba_graph(1500, 3, &mut rng(5));
        let t_hk = Csr::from_graph(&hk).triangle_count();
        let t_ba = Csr::from_graph(&ba).triangle_count();
        assert!(t_hk > t_ba, "triad closure: {t_hk} vs {t_ba}");
    }

    #[test]
    fn community_graph_is_modular() {
        let g = community_graph(1000, 5, 4000, 400, &mut rng(6));
        let within = g
            .edges
            .iter()
            .filter(|e| (e.u as usize / 200) == (e.v as usize / 200))
            .count();
        assert!(within as f64 / g.m() as f64 > 0.8);
    }

    #[test]
    fn road_graph_low_degree() {
        let g = road_graph(50, &mut rng(7));
        let deg = g.degrees();
        assert!(*deg.iter().max().unwrap() <= 8);
        assert!(g.avg_degree() > 2.0 && g.avg_degree() < 5.0);
    }

    #[test]
    fn reddit_like_in_band() {
        for seed in 0..5 {
            let g = reddit_like(&mut rng(100 + seed));
            assert!(g.m() >= 9_000 && g.m() <= 60_000, "m = {}", g.m());
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let a = ba_graph(300, 2, &mut rng(42));
        let b = ba_graph(300, 2, &mut rng(42));
        assert_eq!(a.edges, b.edges);
    }
}
