//! # stream-descriptors
//!
//! A production-grade reproduction of **"Computing Graph Descriptors on Edge
//! Streams"** (Hassan, Ali, Khan, Shabbir, Abbas — ACM TKDD 2022): streaming
//! algorithms that compute three graph descriptors — **GABE** (graphlet
//! amounts via budgeted estimates), **MAEVE** (moments of vertex attributes)
//! and **SANTA** (spectral attributes via Taylor approximation) — over *edge
//! streams* while storing at most `b` edges (the *budget*).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the streaming data-pipeline coordinator: edge
//!   streams, reservoir sampling, edge-centric subgraph estimation,
//!   Tri-Fly-style master/worker fan-out, classification and the experiment
//!   harness.  Rust owns the entire request path.
//! * **L2 (jax, build time)** — descriptor finalization and analytics
//!   compute graphs, AOT-lowered to HLO text under `artifacts/` and executed
//!   from [`runtime`] via PJRT when the `pjrt` cargo feature is enabled; by
//!   default the same call surface is served by the pure-rust native
//!   backend ([`runtime::native`]), so the crate builds and runs on
//!   machines without any XLA toolchain.
//! * **L1 (Pallas, build time)** — the compute hot-spots inside the L2
//!   graphs (tiled pairwise distances, masked moments, ψ_j evaluation,
//!   blocked Laplacian powers), lowered with `interpret=True`.
//!
//! Beyond the paper's finite single pass, the crate serves the live-
//! traffic scenario through [`sampling::window`]: one
//! [`WindowPolicy`](sampling::WindowPolicy) knob switches every estimator
//! and the coordinator between full-history, sliding-window and
//! exponential-decay semantics, with per-stride descriptor snapshots
//! merged at coordinator barriers.
//!
//! Start with `README.md` for the five-minute tour; `DESIGN.md` has the
//! full system inventory and experiment index.

// Documentation contract (ISSUE 5, finished in ISSUE 9): every public
// item in the crate is documented — the last module-level allows are
// gone, and `tools/repro-lint` fails CI if one reappears.  The CI `docs`
// job builds rustdoc with `-D warnings`, so regressions fail the build.
#![warn(missing_docs)]
// Panic-hygiene contract (warn since ISSUE 7, deny since ISSUE 9):
// non-test library code never calls `unwrap()` on a fallible path —
// recoverable failures thread `crate::Result`, provably-infallible
// unwraps are `expect`ed with the invariant spelled out, and deliberate
// aborts carry a `repro-lint: allow(panic-hygiene)` marker with the
// reason.  Tests are exempt (a failed unwrap *is* the assertion there).
// `tools/repro-lint` enforces the same contract textually, so it also
// covers cfg-gated code clippy happens not to compile.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod analyze;
pub mod checkpoint;
pub mod classify;
pub mod coordinator;
pub mod count;
pub mod descriptors;
pub mod exact;
pub mod experiments;
pub mod gen;
pub mod graph;
pub mod linalg;
pub mod runtime;
pub mod sampling;
pub mod util;

/// Crate-wide result alias over the in-tree error type ([`util::err`]).
pub type Result<T> = std::result::Result<T, util::err::Error>;
