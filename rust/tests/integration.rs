//! Integration tests: cross-module flows (stream → estimator → finalize →
//! classify) and runtime-backed paths when artifacts are present.

use stream_descriptors::analyze::canberra;
use stream_descriptors::classify::{cross_validate, DistanceMatrix, Metric};
use stream_descriptors::coordinator::{
    run_pipeline, CoordinatorConfig, DescriptorKind, PlacementPolicy, WorkerEstimate,
};
use stream_descriptors::count::idx;
use stream_descriptors::descriptors::psi::{psi_from_eigenvalues, psi_from_traces};
use stream_descriptors::descriptors::santa::SantaEstimator;
use stream_descriptors::descriptors::{gabe::GabeEstimator, maeve::MaeveEstimator};
use stream_descriptors::exact;
use stream_descriptors::gen;
use stream_descriptors::gen::datasets::make_dataset;
use stream_descriptors::graph::csr::Csr;
use stream_descriptors::graph::stream::{
    preprocess_pairs, EdgeStream, FileStream, VecStream,
};
use stream_descriptors::linalg::symmetric_eigenvalues;
use stream_descriptors::runtime::runtime_or_skip;
use stream_descriptors::util::rng::Pcg64;

/// File-backed stream → two-pass SANTA → same traces as in-memory stream.
#[test]
fn file_stream_two_pass_equals_vec_stream() {
    let g = gen::er_graph(200, 600, &mut Pcg64::seed_from_u64(1));
    let dir = std::env::temp_dir().join(format!("sd-int-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("edges.txt");
    stream_descriptors::graph::stream::write_edge_list(&path, &g.edges).unwrap();

    let mut fs = FileStream::open(&path).unwrap();
    let a = SantaEstimator::new(g.m()).run(&mut fs);
    let mut vs = VecStream::new(g.edges.clone());
    let b = SantaEstimator::new(g.m()).run(&mut vs);
    for k in 0..5 {
        assert!((a.traces[k] - b.traces[k]).abs() < 1e-12);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE 5 end-to-end: a windowed run over a *file* stream equals the
/// same windowed run over the in-memory stream, snapshots included, and
/// the sliding sample is genuinely bounded by the window.
#[test]
fn windowed_series_over_file_stream_equals_vec_stream() {
    use stream_descriptors::sampling::{WindowConfig, WindowPolicy};
    let g = gen::powerlaw_cluster_graph(150, 3, 0.5, &mut Pcg64::seed_from_u64(15));
    let dir = std::env::temp_dir().join(format!("sd-win-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("edges.txt");
    stream_descriptors::graph::stream::write_edge_list(&path, &g.edges).unwrap();

    let w = g.m() / 3;
    let est = GabeEstimator::new(g.m() / 4)
        .with_seed(9)
        .with_window(WindowConfig::new(WindowPolicy::Sliding { w }).with_stride(w / 2));
    let mut fs = FileStream::open(&path).unwrap();
    let a = est.run_series(&mut fs);
    let mut vs = VecStream::new(g.edges.clone());
    let b = est.run_series(&mut vs);
    assert_eq!(a.snapshots.len(), b.snapshots.len());
    assert!(!a.snapshots.is_empty());
    for (x, y) in a.snapshots.iter().zip(&b.snapshots) {
        assert_eq!(x.t, y.t);
        assert_eq!(x.estimate.counts, y.estimate.counts);
        assert_eq!(x.estimate.ne, y.estimate.ne);
    }
    assert_eq!(a.last.counts, b.last.counts);
    assert_eq!(a.last.ne, w as u64, "final estimate describes the window");
    std::fs::remove_dir_all(&dir).ok();
}

/// Raw-pair preprocessing → stream → estimator is robust to junk input.
#[test]
fn preprocessing_pipeline_end_to_end() {
    let pairs: Vec<(u32, u32)> = vec![
        (100, 200),
        (200, 100), // duplicate (reversed)
        (5, 5),     // self loop
        (100, 300),
        (200, 300),
        (300, 400),
    ];
    let edges = preprocess_pairs(pairs, 3);
    assert_eq!(edges.len(), 4);
    let mut s = VecStream::new(edges);
    let est = GabeEstimator::new(100).run(&mut s);
    assert_eq!(est.ne, 4);
    assert_eq!(est.nv, 4); // dense relabel 0..3
}

/// Full classification flow on a small two-class dataset: streamed
/// descriptors must beat chance decisively.
#[test]
fn streamed_descriptors_classify_above_chance() {
    let ds = make_dataset("OHSU", 0.6, 5);
    let descs: Vec<Vec<f64>> = ds
        .graphs
        .iter()
        .enumerate()
        .map(|(i, g)| {
            let mut s = VecStream::shuffled(g.edges.clone(), i as u64);
            GabeEstimator::new((g.m() / 2).max(2))
                .with_seed(i as u64)
                .run(&mut s)
                .descriptor()
                .to_vec()
        })
        .collect();
    let dm = DistanceMatrix::compute(&descs, Metric::Canberra);
    let cv = cross_validate(&dm, &ds.labels, 10, 3, 1);
    assert!(cv.accuracy > 60.0, "accuracy {}", cv.accuracy);
}

/// Coordinator + SANTA + ψ finalization against the exact spectral path.
#[test]
fn pipeline_santa_close_to_spectrum() {
    let g = gen::er_graph(300, 900, &mut Pcg64::seed_from_u64(9));
    let cfg = CoordinatorConfig {
        workers: 4,
        budget: g.m() / 2,
        chunk_size: 128,
        queue_depth: 4,
        seed: 13,
        ..Default::default()
    };
    let mut s = VecStream::shuffled(g.edges.clone(), 2);
    let r = run_pipeline(&mut s, DescriptorKind::Santa { exact_wedges: false }, &cfg)
        .expect("pipeline");
    let WorkerEstimate::Santa(est) = &r.averaged else { unreachable!() };
    let psi = psi_from_traces(&est.traces, est.nv as f64);
    let eigs = symmetric_eigenvalues(&Csr::from_graph(&g).normalized_laplacian(), g.n);
    let truth = psi_from_eigenvalues(&eigs, g.n as f64);
    // HC variant, small j: tight agreement
    for k in 0..20 {
        let rel = (psi[2][k] - truth[2][k]).abs() / truth[2][k].abs();
        assert!(rel < 0.05, "k={k}: {} vs {}", psi[2][k], truth[2][k]);
    }
}

/// Exact-budget MAEVE through the coordinator equals the single-threaded
/// exact baseline, independent of worker count and chunking.
#[test]
fn coordinator_invariant_to_chunking() {
    let g = gen::ba_graph(400, 3, &mut Pcg64::seed_from_u64(21));
    let exact = exact::maeve_exact(&g);
    for (workers, chunk) in [(1, 1), (3, 17), (7, 1024)] {
        let cfg = CoordinatorConfig {
            workers,
            budget: g.m(),
            chunk_size: chunk,
            queue_depth: 2,
            seed: 5,
            ..Default::default()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 1);
        let r = run_pipeline(&mut s, DescriptorKind::Maeve, &cfg).expect("pipeline");
        let WorkerEstimate::Maeve(est) = &r.averaged else { unreachable!() };
        for v in 0..g.n {
            assert!((est.triangles[v] - exact.triangles[v]).abs() < 1e-9);
            assert!((est.paths[v] - exact.paths[v]).abs() < 1e-9);
        }
    }
}

/// NUMA placement end-to-end on the *discovered* machine topology (unit
/// suites use synthetic layouts; this is the real-hardware leg): every
/// policy must reproduce the unpinned estimate bit-for-bit, and the
/// per-node fan-out must never allocate more replicas than
/// `chunks × nodes`.
#[test]
fn placement_policies_bit_identical_on_real_topology() {
    let g = gen::powerlaw_cluster_graph(400, 3, 0.4, &mut Pcg64::seed_from_u64(77));
    let run = |placement| {
        let cfg = CoordinatorConfig {
            workers: 4,
            budget: g.m() / 3,
            chunk_size: 64,
            queue_depth: 4,
            seed: 11,
            placement,
            ..Default::default()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 5);
        run_pipeline(&mut s, DescriptorKind::Gabe, &cfg).expect("pipeline")
    };
    let base = run(PlacementPolicy::None);
    let WorkerEstimate::Gabe(base_est) = &base.averaged else { unreachable!() };
    for placement in [PlacementPolicy::Compact, PlacementPolicy::Scatter] {
        let r = run(placement);
        let WorkerEstimate::Gabe(est) = &r.averaged else { unreachable!() };
        assert_eq!(est.counts, base_est.counts, "{placement} diverged from unpinned");
        let p = &r.placement;
        assert!(p.nodes_used >= 1 && p.nodes_used <= p.nodes);
        assert_eq!(p.chunk_replicas, p.chunks * p.nodes_used as u64, "{p:?}");
    }
    assert_eq!(base.placement.chunk_replicas, base.placement.chunks);
}

/// L2-runtime end-to-end: streamed estimates finalized by the runtime
/// (native backend on default builds, PJRT artifacts with `--features
/// pjrt`), distance kernel vs rust metric, classification accuracy sane.
#[test]
fn runtime_end_to_end_classification() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = make_dataset("OHSU", 0.4, 7);
    let raw: Vec<_> = ds
        .graphs
        .iter()
        .enumerate()
        .map(|(i, g)| {
            let mut s = VecStream::shuffled(g.edges.clone(), i as u64);
            SantaEstimator::new((g.m() / 2).max(2))
                .with_seed(i as u64)
                .run(&mut s)
        })
        .collect();
    let traces: Vec<[f64; 5]> = raw.iter().map(|e| e.traces).collect();
    let nv: Vec<f64> = raw.iter().map(|e| e.nv as f64).collect();
    let finalized = rt.santa_psi(&traces, &nv).unwrap();
    let descs: Vec<Vec<f64>> = finalized
        .iter()
        .map(|(psi, _, _)| psi[2 * 60..3 * 60].to_vec())
        .collect();
    // cross-check vs rust mirror
    for (d, e) in descs.iter().zip(&raw) {
        let mirror = psi_from_traces(&e.traces, e.nv as f64)[2];
        for (a, b) in d.iter().zip(&mirror) {
            assert!((a - b).abs() < 1e-3 * b.abs().max(1e-3));
        }
    }
    let (_, euc) = rt.pairwise_dist(&descs, &descs).unwrap();
    let dm = DistanceMatrix::from_raw(descs.len(), euc);
    let cv = cross_validate(&dm, &ds.labels, 5, 2, 3);
    assert!(cv.accuracy > 40.0);
}

/// Without the `pjrt` feature the runtime must resolve to the native
/// backend (never a skip), and its finalizers must agree with the in-crate
/// estimator mirrors end-to-end.
#[test]
#[cfg(not(feature = "pjrt"))]
fn native_runtime_always_available_and_exact() {
    let rt = runtime_or_skip().expect("native runtime must always load");
    assert!(rt.is_native());
    let g = gen::er_graph(60, 150, &mut Pcg64::seed_from_u64(77));
    let est = exact::gabe_exact(&g);
    let phi = rt.gabe_finalize(&[est.counts], &[est.nv as f64]).unwrap();
    for (a, b) in phi[0].iter().zip(&est.descriptor()) {
        assert!((a - b).abs() <= 1e-10, "{a} vs {b}");
    }
    let sest = exact::santa_exact(&g);
    let lap = Csr::from_graph(&g).normalized_laplacian();
    let traces = rt.trace_powers(&lap, g.n).unwrap();
    for k in 1..5 {
        assert!(
            (traces[k] - sest.traces[k]).abs() < 1e-6 * sest.traces[k].abs().max(1.0),
            "tr(L^{k})"
        );
    }
}

/// MAEVE features derived from a streamed estimate satisfy Theorem 3's
/// identities against an exact recount on the same graph.
#[test]
fn theorem3_identities_hold_end_to_end() {
    let g = gen::powerlaw_cluster_graph(120, 3, 0.7, &mut Pcg64::seed_from_u64(31));
    let est = exact::maeve_exact(&g);
    let feats = est.features();
    let csr = Csr::from_graph(&g);
    for v in 0..g.n {
        let d = csr.degree(v as u32) as f64;
        // egonet edge count by direct inspection
        let nb = csr.neighbors(v as u32);
        let mut ego = d;
        for (i, &a) in nb.iter().enumerate() {
            for &b in &nb[i + 1..] {
                if csr.has_edge(a, b) {
                    ego += 1.0;
                }
            }
        }
        assert!((feats[3][v] - ego).abs() < 1e-9, "egonet edges at {v}");
    }
}

/// The GABE vector of a disjoint union relates sanely to its parts
/// (connected counts add; a quick linearity sanity check).
#[test]
fn counts_additive_over_disjoint_union() {
    let g1 = gen::er_graph(40, 120, &mut Pcg64::seed_from_u64(41));
    let shift = g1.n as u32;
    let mut pairs: Vec<(u32, u32)> = g1.edges.iter().map(|e| (e.u, e.v)).collect();
    pairs.extend(g1.edges.iter().map(|e| (e.u + shift, e.v + shift)));
    let union = stream_descriptors::graph::Graph::from_pairs(pairs);
    let a = exact::gabe_exact(&g1).counts;
    let u = exact::gabe_exact(&union).counts;
    for gi in [idx::TRIANGLE, idx::PATH4, idx::CYCLE4, idx::PAW, idx::DIAMOND, idx::K4] {
        assert!((u[gi] - 2.0 * a[gi]).abs() < 1e-6, "graphlet {gi}");
    }
}

/// Descriptor distance between a graph and itself under different stream
/// orders shrinks as budget grows (stability check used by Fig. 5).
#[test]
fn estimate_stability_improves_with_budget() {
    let g = gen::reddit_like(&mut Pcg64::seed_from_u64(51));
    let spread = |frac: f64| {
        let b = (g.m() as f64 * frac) as usize;
        let d1 = {
            let mut s = VecStream::shuffled(g.edges.clone(), 1);
            GabeEstimator::new(b).with_seed(1).run(&mut s).descriptor()
        };
        let d2 = {
            let mut s = VecStream::shuffled(g.edges.clone(), 2);
            GabeEstimator::new(b).with_seed(2).run(&mut s).descriptor()
        };
        canberra(&d1, &d2)
    };
    let lo = spread(0.1);
    let hi = spread(0.8);
    assert!(hi < lo, "spread at 0.8|E| ({hi}) should beat 0.1|E| ({lo})");
}

/// Stream length mismatch handling: estimators cope with empty streams.
#[test]
fn empty_and_tiny_streams() {
    let mut s = VecStream::new(Vec::new());
    let est = GabeEstimator::new(10).run(&mut s);
    assert_eq!(est.nv, 0);
    assert_eq!(est.ne, 0);
    assert!(est.counts.iter().all(|c| *c == 0.0));

    let mut s = VecStream::new(vec![stream_descriptors::graph::Edge::new(0, 1)]);
    let est = MaeveEstimator::new(10).run(&mut s);
    assert_eq!(est.nv, 2);
    let d = est.descriptor();
    assert!(d.iter().all(|x| x.is_finite()));
}
