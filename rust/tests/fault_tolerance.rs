//! ISSUE 7 differential proof, run as a blocking CI job (`chaos`):
//!
//! 1. a run resumed from a checkpoint at edge index `k` is **bit-for-bit
//!    identical** to the uninterrupted run — for all three descriptors,
//!    through both the direct runner and the pipeline;
//! 2. killing `K` of `W` workers still completes, flags
//!    `health.degraded`, and the arrival-weighted merge of the survivors
//!    stays within the documented tolerance (exact budgets ⇒ float
//!    rounding only, ≤ 1e-9 relative — see DESIGN.md §10).
//!
//! Every pipeline test injects an explicit [`FaultPlan`] (possibly the
//! empty one): an injected plan always overrides
//! `STREAM_DESCRIPTORS_FAULT_PLAN`, so this suite stays deterministic
//! under the chaos job's environment plans.  No sleeps, no flakes: fault
//! triggers are arrival-clock comparisons, nothing times anything.

use stream_descriptors::checkpoint::{resume_direct, run_direct, DirectConfig};
use stream_descriptors::coordinator::{
    run_pipeline, CoordinatorConfig, DescriptorKind, WorkerEstimate,
};
use stream_descriptors::gen;
use stream_descriptors::graph::stream::VecStream;
use stream_descriptors::graph::Graph;
use stream_descriptors::sampling::{Backend, WindowConfig, WindowPolicy};
use stream_descriptors::util::fault::FaultPlan;
use stream_descriptors::util::rng::Pcg64;
use stream_descriptors::util::tmp::TempDir;

const KINDS: [DescriptorKind; 3] = [
    DescriptorKind::Gabe,
    DescriptorKind::Maeve,
    DescriptorKind::Santa { exact_wedges: false },
];

fn test_graph() -> Graph {
    gen::powerlaw_cluster_graph(180, 3, 0.5, &mut Pcg64::seed_from_u64(41))
}

fn assert_bit_identical(a: &WorkerEstimate, b: &WorkerEstimate, what: &str) {
    match (a, b) {
        (WorkerEstimate::Gabe(x), WorkerEstimate::Gabe(y)) => {
            for (p, q) in x.counts.iter().zip(&y.counts) {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: {p} vs {q}");
            }
        }
        (WorkerEstimate::Maeve(x), WorkerEstimate::Maeve(y)) => {
            let xs = x.triangles.iter().chain(&x.paths);
            let ys = y.triangles.iter().chain(&y.paths);
            for (p, q) in xs.zip(ys) {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: {p} vs {q}");
            }
        }
        (WorkerEstimate::Santa(x), WorkerEstimate::Santa(y)) => {
            for (p, q) in x.traces.iter().zip(&y.traces) {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: {p} vs {q}");
            }
        }
        _ => panic!("{what}: descriptor kinds differ"),
    }
}

fn assert_close(a: &WorkerEstimate, b: &WorkerEstimate, rel: f64, what: &str) {
    let pairs: (Vec<f64>, Vec<f64>) = match (a, b) {
        (WorkerEstimate::Gabe(x), WorkerEstimate::Gabe(y)) => {
            (x.counts.to_vec(), y.counts.to_vec())
        }
        (WorkerEstimate::Maeve(x), WorkerEstimate::Maeve(y)) => (
            x.triangles.iter().chain(&x.paths).copied().collect(),
            y.triangles.iter().chain(&y.paths).copied().collect(),
        ),
        (WorkerEstimate::Santa(x), WorkerEstimate::Santa(y)) => {
            (x.traces.to_vec(), y.traces.to_vec())
        }
        _ => panic!("{what}: descriptor kinds differ"),
    };
    for (p, q) in pairs.0.iter().zip(&pairs.1) {
        assert!((p - q).abs() <= rel * q.abs().max(1.0), "{what}: {p} vs {q}");
    }
}

/// Differential proof 1a, pipeline: interrupt at ~2/3 of the stream with
/// checkpoints on, resume from the file, and land bit-for-bit on the
/// uninterrupted run — all three descriptors, sliding window included.
#[test]
fn pipeline_resume_is_bit_identical_for_every_descriptor() {
    let g = test_graph();
    let m = g.m() as u64;
    for kind in KINDS {
        let dir = TempDir::new("ft-pipe").unwrap();
        let ckpt = dir.path().join("run.sdc");
        let base = CoordinatorConfig {
            workers: 2,
            budget: g.m() / 3,
            chunk_size: 16,
            queue_depth: 2,
            seed: 29,
            window: WindowConfig {
                policy: WindowPolicy::Sliding { w: g.m() / 2 },
                stride: 0,
            },
            fault: Some(FaultPlan::none()),
            ..Default::default()
        };

        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let full = run_pipeline(&mut s, kind, &base).unwrap();

        let interrupted = CoordinatorConfig {
            checkpoint_every: m / 4,
            checkpoint_path: Some(ckpt.clone()),
            stop_after: 2 * m / 3,
            ..base.clone()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let partial = run_pipeline(&mut s, kind, &interrupted).unwrap();
        assert!(partial.health.checkpoints_written >= 1, "{kind:?}: {:?}", partial.health);

        let resumed_cfg = CoordinatorConfig { resume: Some(ckpt), ..base.clone() };
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let resumed = run_pipeline(&mut s, kind, &resumed_cfg).unwrap();
        assert_eq!(resumed.edges, m, "{kind:?}");
        assert_bit_identical(&full.averaged, &resumed.averaged, "averaged");
        for (i, (a, b)) in full.per_worker.iter().zip(&resumed.per_worker).enumerate() {
            assert_bit_identical(a, b, &format!("{kind:?} worker {i}"));
        }
    }
}

/// Differential proof 1b, direct runner: same contract without a
/// coordinator in the loop (the checkpoint carries the single sequential
/// estimator + stream cursor).
#[test]
fn direct_resume_is_bit_identical_for_every_descriptor() {
    let g = test_graph();
    let m = g.m() as u64;
    for kind in KINDS {
        let dir = TempDir::new("ft-direct").unwrap();
        let ckpt = dir.path().join("run.sdc");
        let plain = DirectConfig {
            kind,
            budget: g.m() / 3,
            seed: 29,
            ..Default::default()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let full = run_direct(&mut s, &plain).unwrap();

        let ckpting = DirectConfig {
            checkpoint_every: (m / 3).max(1),
            checkpoint_path: Some(ckpt.clone()),
            ..plain.clone()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let with_ckpts = run_direct(&mut s, &ckpting).unwrap();
        assert!(with_ckpts.checkpoints_written >= 1, "{kind:?}");
        assert_bit_identical(&full.estimate, &with_ckpts.estimate, "checkpointing perturbed");

        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let resumed = resume_direct(&mut s, &ckpt, &plain).unwrap();
        let at = resumed.resumed_at.expect("must resume mid-stream");
        assert!(at > 0 && at < m, "{kind:?}: resumed at {at} of {m}");
        assert_bit_identical(&full.estimate, &resumed.estimate, "resume diverged");
    }
}

/// Differential proof 2: kill 1 of 3 workers (a `lose` fault re-fires on
/// every restart, exhausting the budget).  The run completes, is flagged
/// degraded, and — with exact budgets, where every worker's estimate is
/// the census — the survivors' weighted merge matches the clean run's
/// average to float rounding.
#[test]
fn degraded_run_completes_within_documented_tolerance() {
    let g = test_graph();
    for kind in KINDS {
        let base = CoordinatorConfig {
            workers: 3,
            budget: g.m(),
            chunk_size: 32,
            queue_depth: 2,
            seed: 31,
            max_restarts: 1,
            fault: Some(FaultPlan::none()),
            ..Default::default()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 11);
        let clean = run_pipeline(&mut s, kind, &base).unwrap();
        assert!(!clean.health.degraded);

        let lossy = CoordinatorConfig {
            fault: Some(FaultPlan::parse("lose@1:401").unwrap()),
            ..base.clone()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 11);
        let degraded = run_pipeline(&mut s, kind, &lossy).unwrap();
        assert!(degraded.health.degraded, "{kind:?}");
        assert_eq!(degraded.health.lost_workers, vec![1], "{kind:?}");
        assert_eq!(degraded.per_worker.len(), 2, "{kind:?}: survivors only");
        assert!(degraded.health.faults_injected >= 2, "{kind:?}: lose re-fires on replay");
        assert_close(&degraded.averaged, &clean.averaged, 1e-9, &format!("{kind:?}"));
    }
}

/// A one-shot panic is absorbed: restore + replay reproduces the
/// fault-free run bit-for-bit, and the health report says exactly one
/// restart happened.
#[test]
fn absorbed_panic_reproduces_the_clean_run() {
    let g = test_graph();
    let at = g.m() as u64 / 2;
    let base = CoordinatorConfig {
        workers: 2,
        budget: g.m() / 4,
        chunk_size: 64,
        queue_depth: 2,
        seed: 37,
        fault: Some(FaultPlan::none()),
        ..Default::default()
    };
    let mut s = VecStream::shuffled(g.edges.clone(), 13);
    let clean = run_pipeline(&mut s, DescriptorKind::Gabe, &base).unwrap();

    let plan = FaultPlan::parse(&format!("panic@0:{at}; stall@1:{at}")).unwrap();
    let faulty_cfg = CoordinatorConfig { fault: Some(plan), ..base.clone() };
    let mut s = VecStream::shuffled(g.edges.clone(), 13);
    let faulty = run_pipeline(&mut s, DescriptorKind::Gabe, &faulty_cfg).unwrap();
    assert_eq!(faulty.health.restarts, 1);
    assert_eq!(faulty.health.faults_injected, 2);
    assert!(!faulty.health.degraded);
    assert_bit_identical(&clean.averaged, &faulty.averaged, "absorbed panic");
}

/// Lost-*shard* leg (ISSUE 10): in sketch shard mode each chunk reaches
/// exactly one worker, so losing a worker loses its share of the stream
/// — the run must complete, flag `degraded`, and the survivors' merged
/// state must be **bit-for-bit** a direct sketch pass over exactly the
/// surviving chunks (chunk `c` routes round-robin to worker `c % W`; a
/// permanently lost worker drains and discards its queue).
///
/// GABE and MAEVE only: SANTA's pass-1 degree profile is computed by
/// the master over the *full* stream, so its degraded estimate has no
/// direct-run twin over the surviving subsequence.
#[test]
fn lost_sketch_shard_merges_exactly_the_surviving_chunks() {
    let g = test_graph();
    let backend = Backend::Sketch { width: 32, depth: 3 };
    let (workers, chunk_size, lost) = (3usize, 32usize, 1usize);

    // the stream order the pipeline will see, pre-shuffled so the test
    // can slice out the chunks the lost worker swallowed
    let mut order = g.edges.clone();
    Pcg64::seed_from_u64(19).shuffle(&mut order);
    let surviving: Vec<_> = order
        .chunks(chunk_size)
        .enumerate()
        .filter(|(c, _)| c % workers != lost)
        .flat_map(|(_, chunk)| chunk.iter().copied())
        .collect();

    for kind in [DescriptorKind::Gabe, DescriptorKind::Maeve] {
        let cfg = CoordinatorConfig {
            workers,
            budget: g.m() / 3,
            chunk_size,
            queue_depth: 2,
            seed: 47,
            backend,
            max_restarts: 1,
            // `lose` re-fires on the restart replay, exhausting the budget:
            // worker 1 is declared lost on its first chunk and every chunk
            // routed to it afterwards is discarded
            fault: Some(FaultPlan::parse(&format!("lose@{lost}:5")).unwrap()),
            ..Default::default()
        };
        let mut s = VecStream::new(order.clone());
        let degraded = run_pipeline(&mut s, kind, &cfg).unwrap();
        assert!(degraded.health.degraded, "{kind:?}");
        assert_eq!(degraded.health.lost_workers, vec![lost], "{kind:?}");
        assert_eq!(degraded.per_worker.len(), workers - 1, "{kind:?}: survivors only");
        assert_eq!(degraded.edges, g.m() as u64, "{kind:?}: master must drain the stream");

        let direct_cfg = DirectConfig {
            kind,
            budget: g.m() / 3,
            seed: 47,
            backend,
            ..Default::default()
        };
        let mut s = VecStream::new(surviving.clone());
        let direct = run_direct(&mut s, &direct_cfg).unwrap();
        assert_bit_identical(
            &degraded.averaged,
            &direct.estimate,
            &format!("{kind:?}: degraded merge vs direct run over surviving chunks"),
        );
    }
}

/// Corrupt checkpoints are rejected loudly on resume, never half-loaded:
/// flip one byte in the body and the pipeline refuses the document by
/// checksum before any worker starts.
#[test]
fn pipeline_rejects_a_corrupt_checkpoint() {
    let g = test_graph();
    let m = g.m() as u64;
    let dir = TempDir::new("ft-corrupt").unwrap();
    let ckpt = dir.path().join("run.sdc");
    let cfg = CoordinatorConfig {
        workers: 2,
        budget: g.m() / 3,
        chunk_size: 16,
        queue_depth: 2,
        seed: 43,
        checkpoint_every: m / 3,
        checkpoint_path: Some(ckpt.clone()),
        stop_after: 2 * m / 3,
        fault: Some(FaultPlan::none()),
        ..Default::default()
    };
    let mut s = VecStream::shuffled(g.edges.clone(), 17);
    run_pipeline(&mut s, DescriptorKind::Gabe, &cfg).unwrap();

    let mut bytes = std::fs::read(&ckpt).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&ckpt, &bytes).unwrap();

    let resume_cfg = CoordinatorConfig {
        checkpoint_every: 0,
        checkpoint_path: None,
        stop_after: 0,
        resume: Some(ckpt),
        ..cfg.clone()
    };
    let mut s = VecStream::shuffled(g.edges.clone(), 17);
    let err = run_pipeline(&mut s, DescriptorKind::Gabe, &resume_cfg)
        .expect_err("corrupt checkpoint must be rejected");
    assert!(err.to_string().contains("checksum"), "{err}");
}
