//! ISSUE 8 differential suite for the estimation-backend split:
//!
//! 1. **Reservoir pinning** — the pre-PR constructors (`new` /
//!    `with_seed` / `with_window`) and the unified
//!    [`EstimatorConfig`] path produce **bit-for-bit** identical
//!    estimates on all three descriptors, and the pipeline is
//!    bit-for-bit indifferent to spelling out `Backend::Reservoir`
//!    (the default).  The config refactor must be pure plumbing.
//! 2. **Merge law** — `merge(sketch(A), sketch(B))` equals
//!    `sketch(A ++ B)` exactly: bucket cells are wrapping integer
//!    sums, so the merge is associative and order-blind.  Checked
//!    directly on [`GraphSketch`] and end-to-end through the
//!    coordinator's sharded pipeline, whose worker-state merge must
//!    land bit-for-bit on the single-state direct run.
//! 3. **Validation** — the combinations DESIGN.md §11 rules out
//!    (windows, snapshot strides, pipeline checkpoints, SANTA
//!    `exact_wedges`) are rejected up front with telling errors.

use stream_descriptors::checkpoint::{run_direct, DirectConfig};
use stream_descriptors::coordinator::{
    run_pipeline, CoordinatorConfig, DescriptorKind, WorkerEstimate,
};
use stream_descriptors::descriptors::gabe::GabeEstimator;
use stream_descriptors::descriptors::maeve::MaeveEstimator;
use stream_descriptors::descriptors::santa::{SantaConfig, SantaEstimator};
use stream_descriptors::gen;
use stream_descriptors::graph::stream::VecStream;
use stream_descriptors::graph::{Edge, Graph};
use stream_descriptors::sampling::{
    Backend, EstimatorConfig, GraphSketch, WindowConfig, WindowPolicy,
};
use stream_descriptors::util::fault::FaultPlan;
use stream_descriptors::util::rng::Pcg64;

const KINDS: [DescriptorKind; 3] = [
    DescriptorKind::Gabe,
    DescriptorKind::Maeve,
    DescriptorKind::Santa { exact_wedges: false },
];

fn test_graph() -> Graph {
    gen::powerlaw_cluster_graph(180, 3, 0.5, &mut Pcg64::seed_from_u64(41))
}

fn assert_bit_identical(a: &WorkerEstimate, b: &WorkerEstimate, what: &str) {
    match (a, b) {
        (WorkerEstimate::Gabe(x), WorkerEstimate::Gabe(y)) => {
            assert_eq!((x.nv, x.ne), (y.nv, y.ne), "{what}");
            assert_eq!(x.degrees, y.degrees, "{what}");
            for (p, q) in x.counts.iter().zip(&y.counts) {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: {p} vs {q}");
            }
        }
        (WorkerEstimate::Maeve(x), WorkerEstimate::Maeve(y)) => {
            assert_eq!((x.nv, x.ne), (y.nv, y.ne), "{what}");
            let xs = x.triangles.iter().chain(&x.paths);
            let ys = y.triangles.iter().chain(&y.paths);
            for (p, q) in xs.zip(ys) {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: {p} vs {q}");
            }
        }
        (WorkerEstimate::Santa(x), WorkerEstimate::Santa(y)) => {
            assert_eq!((x.nv, x.ne), (y.nv, y.ne), "{what}");
            for (p, q) in x.traces.iter().zip(&y.traces) {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: {p} vs {q}");
            }
        }
        _ => panic!("{what}: descriptor kinds differ"),
    }
}

/// Differential 1a: every legacy builder chain is a pure delegate of the
/// [`EstimatorConfig`] path — same bits out, descriptor by descriptor,
/// full-history and windowed.
#[test]
fn legacy_builders_delegate_bit_for_bit() {
    let g = test_graph();
    let b = g.m() / 3;
    let windows = [
        WindowConfig::default(),
        WindowConfig::new(WindowPolicy::Sliding { w: g.m() / 2 }).with_stride(g.m() / 5),
    ];
    for window in windows {
        let cfg = EstimatorConfig::new(b).with_seed(9).with_window(window);
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let old = GabeEstimator::new(b).with_seed(9).with_window(window).run(&mut s);
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let new = GabeEstimator::from_config(cfg.clone()).run(&mut s);
        assert_bit_identical(
            &WorkerEstimate::Gabe(old),
            &WorkerEstimate::Gabe(new),
            "gabe builders",
        );

        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let old = MaeveEstimator::new(b).with_seed(9).with_window(window).run(&mut s);
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let new = MaeveEstimator::from_config(cfg.clone()).run(&mut s);
        assert_bit_identical(
            &WorkerEstimate::Maeve(old),
            &WorkerEstimate::Maeve(new),
            "maeve builders",
        );

        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        let old = SantaEstimator::new(b).with_seed(9).with_window(window).run(&mut s);
        let mut s = VecStream::shuffled(g.edges.clone(), 7);
        // the seed sits on the shared config, so `From<EstimatorConfig>`
        // must carry it into SantaConfig unchanged
        let new = SantaEstimator::from_config(cfg.clone()).run(&mut s);
        assert_bit_identical(
            &WorkerEstimate::Santa(old),
            &WorkerEstimate::Santa(new),
            "santa builders",
        );
    }
}

/// Differential 1b: a pipeline that spells out `Backend::Reservoir`
/// is bit-for-bit the default pipeline — the backend knob cannot
/// perturb the pre-PR path.
#[test]
fn reservoir_pipeline_is_indifferent_to_the_backend_field() {
    let g = test_graph();
    for kind in KINDS {
        let base = CoordinatorConfig {
            workers: 3,
            budget: g.m() / 3,
            chunk_size: 64,
            queue_depth: 2,
            seed: 23,
            fault: Some(FaultPlan::none()),
            ..Default::default()
        };
        let explicit = CoordinatorConfig { backend: Backend::Reservoir, ..base.clone() };
        let mut s = VecStream::shuffled(g.edges.clone(), 5);
        let a = run_pipeline(&mut s, kind, &base).unwrap();
        let mut s = VecStream::shuffled(g.edges.clone(), 5);
        let b = run_pipeline(&mut s, kind, &explicit).unwrap();
        assert_bit_identical(&a.averaged, &b.averaged, "explicit reservoir backend");
    }
}

/// The merge law, directly on the sketch: splitting a stream anywhere
/// and merging the two halves' sketches reproduces the single-pass
/// sketch exactly, through every readout channel.
#[test]
fn sketch_merge_matches_the_single_pass() {
    let g = test_graph();
    let mut edges: Vec<Edge> = g.edges.clone();
    Pcg64::seed_from_u64(3).shuffle(&mut edges);
    let mut degrees = vec![0u32; g.n];
    for e in &edges {
        degrees[e.u as usize] += 1;
        degrees[e.v as usize] += 1;
    }

    for cut in [1, edges.len() / 3, edges.len() / 2, edges.len() - 1] {
        let mut whole = GraphSketch::new(32, 3, 0xfab);
        let mut left = GraphSketch::new(32, 3, 0xfab);
        let mut right = GraphSketch::new(32, 3, 0xfab);
        for (i, e) in edges.iter().enumerate() {
            whole.update(e.u, e.v);
            if i < cut { &mut left } else { &mut right }.update(e.u, e.v);
        }
        left.merge(&right).unwrap();

        let (a, b) = (whole.connected_counts(), left.connected_counts());
        for (p, q) in [
            (a.triangle, b.triangle),
            (a.path4, b.path4),
            (a.cycle4, b.cycle4),
            (a.paw, b.paw),
            (a.diamond, b.diamond),
            (a.k4, b.k4),
        ] {
            assert_eq!(p.to_bits(), q.to_bits(), "cut={cut}: counts {p} vs {q}");
        }
        let (wt, wp) = whole.maeve_readout(&degrees);
        let (mt, mp) = left.maeve_readout(&degrees);
        for (p, q) in wt.iter().chain(&wp).zip(mt.iter().chain(&mp)) {
            assert_eq!(p.to_bits(), q.to_bits(), "cut={cut}: maeve {p} vs {q}");
        }
        let ws = whole.santa_traces(g.n as u64, &degrees);
        let ms = left.santa_traces(g.n as u64, &degrees);
        for (p, q) in ws.iter().zip(&ms) {
            assert_eq!(p.to_bits(), q.to_bits(), "cut={cut}: traces {p} vs {q}");
        }
    }

    // merging across geometries or hash seeds is refused
    let err = GraphSketch::new(32, 3, 1).merge(&GraphSketch::new(16, 3, 1)).unwrap_err();
    assert!(err.to_string().contains("geometry"), "{err}");
    let err = GraphSketch::new(32, 3, 1).merge(&GraphSketch::new(32, 3, 2)).unwrap_err();
    assert!(err.to_string().contains("seed"), "{err}");
}

/// The merge law, end-to-end: the sharded sketch pipeline (each chunk
/// to exactly one worker, worker states merged at the barrier) lands
/// bit-for-bit on the single-state direct run — for all three
/// descriptors and any worker count.
#[test]
fn pipeline_sketch_run_matches_the_direct_run() {
    let g = test_graph();
    let backend = Backend::Sketch { width: 32, depth: 3 };
    for kind in KINDS {
        let direct_cfg = DirectConfig {
            kind,
            budget: g.m() / 3,
            seed: 23,
            backend,
            ..Default::default()
        };
        let mut s = VecStream::shuffled(g.edges.clone(), 5);
        let direct = run_direct(&mut s, &direct_cfg).unwrap();

        for workers in [1, 3, 4] {
            let cfg = CoordinatorConfig {
                workers,
                budget: g.m() / 3,
                chunk_size: 32,
                queue_depth: 2,
                seed: 23,
                backend,
                fault: Some(FaultPlan::none()),
                ..Default::default()
            };
            let mut s = VecStream::shuffled(g.edges.clone(), 5);
            let r = run_pipeline(&mut s, kind, &cfg).unwrap();
            assert_bit_identical(
                &r.averaged,
                &direct.estimate,
                &format!("{kind:?} sharded across {workers} workers"),
            );
            assert_eq!(r.edges, g.m() as u64, "{kind:?} W={workers}");
        }
    }
}

/// The ruled-out combinations fail loudly at validation time.
#[test]
fn invalid_sketch_combinations_are_rejected() {
    let sk = Backend::Sketch { width: 32, depth: 3 };
    // geometry floors
    let err = EstimatorConfig::new(8)
        .with_backend(Backend::Sketch { width: 1, depth: 3 })
        .validate()
        .unwrap_err();
    assert!(err.to_string().contains("width"), "{err}");
    let err = EstimatorConfig::new(8)
        .with_backend(Backend::Sketch { width: 32, depth: 0 })
        .validate()
        .unwrap_err();
    assert!(err.to_string().contains("depth"), "{err}");
    // no eviction path => no windows
    let err = EstimatorConfig::new(8)
        .with_window(WindowConfig::new(WindowPolicy::Sliding { w: 5 }))
        .with_backend(sk)
        .validate()
        .unwrap_err();
    assert!(err.to_string().contains("windowed"), "{err}");
    // sharded pipeline: no snapshot strides, no checkpoints
    let base = CoordinatorConfig { backend: sk, ..Default::default() };
    let err = CoordinatorConfig {
        window: WindowConfig::default().with_stride(10),
        ..base.clone()
    }
    .validate()
    .unwrap_err();
    assert!(err.to_string().contains("stride"), "{err}");
    let err = CoordinatorConfig {
        checkpoint_every: 5,
        checkpoint_path: Some("x.sdc".into()),
        ..base.clone()
    }
    .validate()
    .unwrap_err();
    assert!(err.to_string().contains("checkpoint"), "{err}");
    // SANTA's closed-form wedge term needs the reservoir's sample graph
    let err = SantaConfig::from(EstimatorConfig::new(8).with_backend(sk))
        .with_exact_wedges(true)
        .validate()
        .unwrap_err();
    assert!(err.to_string().contains("exact_wedges"), "{err}");
    // a direct run does support sketch checkpoints — single state,
    // single clock — so only the exact_wedges combination is refused
    let ok = DirectConfig {
        kind: DescriptorKind::Santa { exact_wedges: false },
        budget: 8,
        backend: sk,
        checkpoint_every: 10,
        checkpoint_path: Some("x.sdc".into()),
        ..Default::default()
    };
    ok.validate().unwrap();
    let err = DirectConfig {
        kind: DescriptorKind::Santa { exact_wedges: true },
        ..ok.clone()
    }
    .validate()
    .unwrap_err();
    assert!(err.to_string().contains("exact_wedges"), "{err}");
}

/// Sanity on the estimates themselves: sketch-backed runs return
/// finite, non-negative descriptors in the right ballpark of the
/// exact references (tight accuracy is `repro sketch`'s job).
#[test]
fn sketch_estimates_are_finite_and_plausible() {
    let g = test_graph();
    let exact = stream_descriptors::exact::gabe_exact(&g);
    let cfg = EstimatorConfig::new(g.m() / 3)
        .with_seed(17)
        .with_backend(Backend::Sketch { width: 256, depth: 4 });
    let mut s = VecStream::shuffled(g.edges.clone(), 11);
    let est = GabeEstimator::from_config(cfg.clone()).run(&mut s);
    assert_eq!(est.ne, g.m() as u64);
    assert_eq!(est.nv, g.n as u64);
    for (i, c) in est.counts.iter().enumerate() {
        assert!(c.is_finite(), "count {i} not finite");
    }
    // triangles: wide sketch on a small graph stays within a loose band
    let ti = stream_descriptors::count::idx::TRIANGLE;
    let (t, e) = (est.counts[ti], exact.counts[ti]);
    assert!(t >= 0.0 && t <= 10.0 * e.max(1.0), "triangles {t} vs exact {e}");

    let mut s = VecStream::shuffled(g.edges.clone(), 11);
    let m = MaeveEstimator::from_config(cfg.clone()).run(&mut s);
    assert!(m.descriptor().iter().all(|x| x.is_finite()));

    let mut s = VecStream::shuffled(g.edges.clone(), 11);
    let sa = SantaEstimator::from_config(cfg).run(&mut s);
    assert!(sa.traces.iter().all(|x| x.is_finite()));
}
