//! Merge-law suite for the first-class `MergeableState` operation
//! (ISSUE 10).  Four legs:
//!
//! 1. sketches: `merge(sk(A), sk(B))` is *bit-for-bit* `sk(A ++ B)` for
//!    arbitrary stream cuts, exercised through the trait;
//! 2. reservoirs: the weighted merge ([`MergedReservoir`]) is invariant
//!    under shard permutation and merge grouping at fixed seed, and
//!    refuses mismatched budgets/merge seeds loudly;
//! 3. statistics: merged-reservoir inclusion frequencies over 2 000
//!    independent trials sit within 3σ of uniform;
//! 4. shard-count sweep: K ∈ {1, 2, 4, 8} keeps GABE/MAEVE/SANTA
//!    descriptors within a pinned tolerance of the direct single-pass
//!    run (exact at full budget, banded at half budget).
//!
//! This is the target the CI `shard-differential` feature-matrix leg
//! runs with forced-scalar kernels.

use stream_descriptors::analyze::mean_relative_error;
use stream_descriptors::checkpoint::{
    hash_partition, run_direct, run_sharded_edges, DirectConfig, ShardConfig,
};
use stream_descriptors::coordinator::{DescriptorKind, WorkerEstimate};
use stream_descriptors::count::idx;
use stream_descriptors::exact;
use stream_descriptors::gen;
use stream_descriptors::graph::stream::VecStream;
use stream_descriptors::graph::{Edge, Graph};
use stream_descriptors::sampling::merge::RESERVOIR_MERGE_SEED;
use stream_descriptors::sampling::{GraphSketch, MergeableState, MergedReservoir, Reservoir};
use stream_descriptors::util::rng::Pcg64;

const KINDS: [DescriptorKind; 3] = [
    DescriptorKind::Gabe,
    DescriptorKind::Maeve,
    DescriptorKind::Santa { exact_wedges: false },
];

fn test_graph(n: usize, seed: u64) -> Graph {
    gen::powerlaw_cluster_graph(n, 3, 0.5, &mut Pcg64::seed_from_u64(seed))
}

fn degree_profile(g: &Graph) -> Vec<u32> {
    let mut deg = vec![0u32; g.n];
    for e in &g.edges {
        deg[e.u as usize] += 1;
        deg[e.v as usize] += 1;
    }
    deg
}

/// Every readout of two sketches, compared at the bit level.
fn assert_sketch_bit_identical(a: &GraphSketch, b: &GraphSketch, degrees: &[u32], what: &str) {
    let (ca, cb) = (a.connected_counts(), b.connected_counts());
    for (p, q) in [
        (ca.triangle, cb.triangle),
        (ca.path4, cb.path4),
        (ca.cycle4, cb.cycle4),
        (ca.paw, cb.paw),
        (ca.diamond, cb.diamond),
        (ca.k4, cb.k4),
    ] {
        assert_eq!(p.to_bits(), q.to_bits(), "{what}: counts {p} vs {q}");
    }
    let (at, ap) = a.maeve_readout(degrees);
    let (bt, bp) = b.maeve_readout(degrees);
    for (p, q) in at.iter().chain(&ap).zip(bt.iter().chain(&bp)) {
        assert_eq!(p.to_bits(), q.to_bits(), "{what}: maeve {p} vs {q}");
    }
    let nv = degrees.len() as u64;
    for (p, q) in a.santa_traces(nv, degrees).iter().zip(&b.santa_traces(nv, degrees)) {
        assert_eq!(p.to_bits(), q.to_bits(), "{what}: traces {p} vs {q}");
    }
}

/// Leg 1: the sketch merge law through the trait.  Split one stream at
/// several cut points into three parts, fold the part sketches with
/// `merge_state` in two different orders, and require every readout to
/// agree bit-for-bit with the unsplit sketch.
#[test]
fn sketch_merge_state_is_bit_exact_for_any_cut() {
    let g = test_graph(150, 31);
    let mut edges = g.edges.clone();
    Pcg64::seed_from_u64(5).shuffle(&mut edges);
    let degrees = degree_profile(&g);
    let m = edges.len();

    for (c1, c2) in [(1, 2), (m / 4, m / 2), (m / 3, 2 * m / 3), (m - 2, m - 1)] {
        let mut whole = GraphSketch::new(32, 3, 0x10aa);
        let mut parts: Vec<GraphSketch> =
            (0..3).map(|_| GraphSketch::new(32, 3, 0x10aa)).collect();
        for (i, e) in edges.iter().enumerate() {
            whole.update(e.u, e.v);
            let slot = if i < c1 { 0 } else if i < c2 { 1 } else { 2 };
            parts[slot].update(e.u, e.v);
        }

        // left-to-right fold
        let mut folded = parts[0].clone();
        folded.merge_state(&parts[1]).unwrap();
        folded.merge_state(&parts[2]).unwrap();
        assert_sketch_bit_identical(&whole, &folded, &degrees, "fold(0,1,2)");

        // permuted fold: the merge is commutative entrywise
        let mut permuted = parts[2].clone();
        permuted.merge_state(&parts[0]).unwrap();
        permuted.merge_state(&parts[1]).unwrap();
        assert_sketch_bit_identical(&whole, &permuted, &degrees, "fold(2,0,1)");
    }
}

/// Sketches with different geometry or hash seed never merge — through
/// the trait, so the contract is pinned at the `MergeableState` level.
#[test]
fn sketch_merge_state_rejects_geometry_and_seed_mismatch() {
    let err = GraphSketch::new(32, 3, 1)
        .merge_state(&GraphSketch::new(16, 3, 1))
        .unwrap_err();
    assert!(err.to_string().contains("geometry"), "{err}");
    let err = GraphSketch::new(32, 3, 1)
        .merge_state(&GraphSketch::new(32, 3, 2))
        .unwrap_err();
    assert!(err.to_string().contains("seed"), "{err}");
}

/// Fill a reservoir with a slice of real graph edges.
fn filled_reservoir(budget: usize, edges: &[Edge], rng_seed: u64) -> Reservoir {
    let mut r = Reservoir::new(budget, Pcg64::seed_from_u64(rng_seed));
    for &e in edges {
        r.offer(e);
    }
    r
}

/// Leg 2: the lifted reservoir merge is a commutative, associative
/// monoid action under a fixed merge seed — every permutation and every
/// grouping of four *unequal-length* shards lands on the same value.
#[test]
fn merged_reservoir_is_permutation_and_grouping_invariant() {
    let g = gen::er_graph(120, 420, &mut Pcg64::seed_from_u64(40));
    let mut edges = g.edges.clone();
    Pcg64::seed_from_u64(6).shuffle(&mut edges);
    // unequal contiguous shards: 10%, 20%, 30%, 40% of the stream
    let m = edges.len();
    let cuts = [0, m / 10, 3 * m / 10, 6 * m / 10, m];
    let seed = 0xfeed_f00d_u64;
    let lifted: Vec<MergedReservoir> = (0..4)
        .map(|j| {
            let shard = &edges[cuts[j]..cuts[j + 1]];
            MergedReservoir::from_reservoir(&filled_reservoir(48, shard, 100 + j as u64), seed)
        })
        .collect();

    let fold = |order: &[usize]| -> MergedReservoir {
        let mut acc = lifted[order[0]].clone();
        for &j in &order[1..] {
            acc.merge_state(&lifted[j]).unwrap();
        }
        acc
    };

    let base = fold(&[0, 1, 2, 3]);
    assert_eq!(base.len(), 48, "four full shards overflow the merge budget");
    assert_eq!(base.total_t(), m as u64);

    // all 24 permutations of the left-to-right fold
    for a in 0..4usize {
        for b in (0..4).filter(|&b| b != a) {
            for c in (0..4).filter(|&c| c != a && c != b) {
                let d = 6 - a - b - c;
                let order = [a, b, c, d];
                assert_eq!(base, fold(&order), "fold order {order:?} changed the merge");
            }
        }
    }

    // balanced grouping: (0 ∪ 1) ∪ (2 ∪ 3)
    let mut left = lifted[0].clone();
    left.merge_state(&lifted[1]).unwrap();
    let mut right = lifted[2].clone();
    right.merge_state(&lifted[3]).unwrap();
    left.merge_state(&right).unwrap();
    assert_eq!(base, left, "grouping ((0,1),(2,3)) changed the merge");

    // right-leaning grouping: 0 ∪ (1 ∪ (2 ∪ 3))
    let mut tail = lifted[2].clone();
    tail.merge_state(&lifted[3]).unwrap();
    let mut mid = lifted[1].clone();
    mid.merge_state(&tail).unwrap();
    let mut all = lifted[0].clone();
    all.merge_state(&mid).unwrap();
    assert_eq!(base, all, "grouping (0,(1,(2,3))) changed the merge");
}

/// Mismatched merge parameters are refused loudly, per axis.
#[test]
fn merged_reservoir_rejects_budget_and_seed_mismatch() {
    let edges: Vec<Edge> = (0..40u32).map(|i| Edge::new(i, i + 1)).collect();
    let a = MergedReservoir::from_reservoir(&filled_reservoir(16, &edges[..20], 1), 7);
    let b16 = MergedReservoir::from_reservoir(&filled_reservoir(16, &edges[20..], 2), 7);
    let b8 = MergedReservoir::from_reservoir(&filled_reservoir(8, &edges[20..], 2), 7);
    let b9 = MergedReservoir::from_reservoir(&filled_reservoir(16, &edges[20..], 2), 9);

    let err = a.clone().merge_state(&b8).unwrap_err();
    assert!(err.to_string().contains("budget mismatch"), "{err}");
    let err = a.clone().merge_state(&b9).unwrap_err();
    assert!(err.to_string().contains("merge-seed mismatch"), "{err}");
    a.clone().merge_state(&b16).unwrap();
}

/// Leg 3: statistical correctness.  Split a 600-edge stream round-robin
/// into three equal shards, sample each with an independent reservoir,
/// merge, and repeat over 2 000 independently seeded trials.  Under the
/// weighted merge every stream edge must land in the final sample with
/// probability `b/T` — checked two ways:
///
/// * each shard's contribution to the merged sample is within 3σ of
///   `b/K` per trial (σ from the per-trial hypergeometric variance of a
///   uniform `b`-subset of the `K·b` pooled candidates);
/// * no single edge's inclusion frequency strays past a 5σ guard band
///   (600 simultaneous comparisons make a 3σ band flaky by design, so
///   the per-edge check is a gross-bias guard, not the headline bound).
#[test]
#[cfg_attr(miri, ignore)] // 2 000 merge trials: too slow under miri
fn merged_inclusion_frequencies_are_uniform_within_three_sigma() {
    const T: usize = 600; // stream length
    const K: usize = 3; // shards (round-robin => equal length T/K)
    const B: usize = 60; // per-shard and merged budget
    const TRIALS: usize = 2_000;

    let edges: Vec<Edge> = (0..T as u32).map(|i| Edge::new(i, i + 1)).collect();
    let mut per_edge = vec![0u64; T];
    let mut per_shard = [0u64; K];

    for trial in 0..TRIALS {
        let merge_seed = 0x5eed_0000 + trial as u64;
        let mut lifted: Vec<MergedReservoir> = (0..K)
            .map(|j| {
                let shard: Vec<Edge> = edges.iter().copied().skip(j).step_by(K).collect();
                let r = filled_reservoir(B, &shard, 9_000 + (trial * K + j) as u64);
                assert_eq!(r.len(), B);
                MergedReservoir::from_reservoir(&r, merge_seed)
            })
            .collect();
        let mut merged = lifted.remove(0);
        for other in &lifted {
            merged.merge_state(other).unwrap();
        }
        assert_eq!(merged.len(), B);
        assert_eq!(merged.total_t(), T as u64);
        for item in merged.items() {
            let i = item.edge.u as usize;
            per_edge[i] += 1;
            per_shard[i % K] += 1;
        }
    }

    // headline 3σ bound: shard contributions are uniform.  Per trial the
    // merged sample is a uniform B-subset of the K·B pooled candidates
    // (equal weights), so each shard's count is hypergeometric with
    // mean B/K and variance B·(1/K)(1−1/K)·(KB−B)/(KB−1).
    let n = (K * B) as f64;
    let mean = TRIALS as f64 * B as f64 / K as f64;
    let var_trial =
        B as f64 * (1.0 / K as f64) * (1.0 - 1.0 / K as f64) * (n - B as f64) / (n - 1.0);
    let sigma = (TRIALS as f64 * var_trial).sqrt();
    for (j, &count) in per_shard.iter().enumerate() {
        let dev = (count as f64 - mean).abs();
        assert!(
            dev <= 3.0 * sigma,
            "shard {j}: {count} inclusions vs mean {mean:.1} (|dev| {dev:.1} > 3σ = {:.1})",
            3.0 * sigma
        );
    }

    // per-edge guard band at 5σ: p = B/T for every edge
    let p = B as f64 / T as f64;
    let edge_sigma = (TRIALS as f64 * p * (1.0 - p)).sqrt();
    let expected = TRIALS as f64 * p;
    for (i, &count) in per_edge.iter().enumerate() {
        let dev = (count as f64 - expected).abs();
        assert!(
            dev <= 5.0 * edge_sigma,
            "edge {i}: {count} inclusions vs {expected:.1} (|dev| {dev:.1} > 5σ = {:.1})",
            5.0 * edge_sigma
        );
    }
    let total: u64 = per_edge.iter().sum();
    assert_eq!(total, (TRIALS * B) as u64, "merged sample size drifted");
}

/// Flatten an estimate for the sweep comparisons.
fn summary(est: &WorkerEstimate) -> Vec<f64> {
    match est {
        WorkerEstimate::Gabe(e) => e.descriptor().to_vec(),
        WorkerEstimate::Maeve(e) => e.descriptor().to_vec(),
        WorkerEstimate::Santa(e) => e.traces.to_vec(),
    }
}

fn run_pair(
    edges: &[Edge],
    kind: DescriptorKind,
    budget: usize,
    seed: u64,
    backend: stream_descriptors::sampling::Backend,
    k: usize,
) -> (WorkerEstimate, WorkerEstimate) {
    let dcfg = DirectConfig { kind, budget, seed, backend, ..Default::default() };
    let direct = run_direct(&mut VecStream::new(edges.to_vec()), &dcfg).unwrap();
    let parts = hash_partition(edges, k);
    let scfg = ShardConfig { kind, budget, seed, backend };
    let sharded = run_sharded_edges(&parts, &scfg).unwrap();
    assert_eq!(sharded.edges, direct.edges, "shard passes dropped edges");
    assert_eq!(sharded.per_shard_edges.len(), k);
    (direct.estimate, sharded.estimate)
}

/// Leg 4a, pinned tolerance: at budget ≥ |E| every shard keeps its whole
/// partition and the weighted merge keeps everything, so the merged
/// descriptor agrees with the direct run to rounding for K ∈ {1,2,4,8}
/// and all three descriptors.
#[test]
#[cfg_attr(miri, ignore)] // 12 kind×K sharded runs: too slow under miri
fn shard_count_sweep_is_exact_at_full_budget() {
    let g = test_graph(100, 37);
    let mut edges = g.edges.clone();
    Pcg64::seed_from_u64(8).shuffle(&mut edges);
    for kind in KINDS {
        for k in [1usize, 2, 4, 8] {
            let (direct, sharded) = run_pair(
                &edges,
                kind,
                g.m() + 1,
                11,
                stream_descriptors::sampling::Backend::Reservoir,
                k,
            );
            let (d, s) = (summary(&direct), summary(&sharded));
            for (i, (a, b)) in d.iter().zip(&s).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6 * (1.0 + a.abs()),
                    "{kind:?} K={k} component {i}: direct {a} vs merged {b}"
                );
            }
        }
    }
}

/// Sketch shards merge entrywise, so the sweep is bit-exact at *any*
/// budget for every K.
#[test]
#[cfg_attr(miri, ignore)] // 4 sharded sketch runs: too slow under miri
fn shard_count_sweep_is_bit_exact_for_sketches() {
    let g = test_graph(100, 37);
    for k in [1usize, 2, 4, 8] {
        let (direct, sharded) = run_pair(
            &g.edges,
            DescriptorKind::Gabe,
            32,
            11,
            stream_descriptors::sampling::Backend::sketch_default(),
            k,
        );
        let (d, s) = (summary(&direct), summary(&sharded));
        for (a, b) in d.iter().zip(&s) {
            assert_eq!(a.to_bits(), b.to_bits(), "sketch K={k}: {a} vs {b}");
        }
    }
}

/// Leg 4b, statistical band: at budget |E|/2 the merged estimates stay
/// within generous-but-meaningful bands of the exact counts for every
/// K — large-count GABE components and MAEVE's triangle mass within
/// 100% relative error, SANTA's trace vector (dominated by its exact
/// low-order terms) within 50% mean relative error.
#[test]
#[cfg_attr(miri, ignore)] // 9 kind×K sharded runs: too slow under miri
fn shard_count_sweep_stays_in_band_at_half_budget() {
    let g = test_graph(240, 38);
    let mut edges = g.edges.clone();
    Pcg64::seed_from_u64(9).shuffle(&mut edges);
    let budget = g.m() / 2;

    let gabe_exact = exact::gabe_exact(&g);
    let maeve_exact = exact::maeve_exact(&g);
    let santa_exact = exact::santa_exact(&g);
    let maeve_exact_tri: f64 = maeve_exact.triangles.iter().sum();

    let rel = |truth: f64, est: f64| (est - truth).abs() / truth.max(1.0);

    for k in [2usize, 4, 8] {
        for kind in KINDS {
            let (_, sharded) = run_pair(
                &edges,
                kind,
                budget,
                13,
                stream_descriptors::sampling::Backend::Reservoir,
                k,
            );
            match sharded {
                WorkerEstimate::Gabe(e) => {
                    for (name, i) in [("wedge", idx::WEDGE), ("triangle", idx::TRIANGLE)] {
                        let r = rel(gabe_exact.counts[i], e.counts[i]);
                        assert!(
                            r < 1.0,
                            "gabe K={k} {name}: exact {} vs merged {} (rel {r:.3})",
                            gabe_exact.counts[i],
                            e.counts[i]
                        );
                    }
                }
                WorkerEstimate::Maeve(e) => {
                    let tri: f64 = e.triangles.iter().sum();
                    let r = rel(maeve_exact_tri, tri);
                    assert!(
                        r < 1.0,
                        "maeve K={k} triangle mass: exact {maeve_exact_tri} vs merged {tri} \
                         (rel {r:.3})"
                    );
                }
                WorkerEstimate::Santa(e) => {
                    let mre = mean_relative_error(&santa_exact.traces, &e.traces);
                    assert!(
                        mre < 0.5,
                        "santa K={k}: traces {:?} vs exact {:?} (MRE {mre:.3})",
                        e.traces,
                        santa_exact.traces
                    );
                }
            }
        }
    }
}

/// The derived shard seeds feed each reservoir a *distinct* RNG stream:
/// two shards over identical edges must not produce identical samples
/// (the double-counted-stream regression the seed-derivation fix pins),
/// while re-running the same shard reproduces its sample exactly.
#[test]
fn shard_runs_use_independent_derived_rng_streams() {
    let g = gen::er_graph(200, 900, &mut Pcg64::seed_from_u64(44));
    // same edges, two different shard indices => the coordinator-derived
    // seeds seed ^ (j · φ64) must disagree
    let parts = vec![g.edges.clone(), g.edges.clone()];
    let cfg = ShardConfig {
        kind: DescriptorKind::Gabe,
        budget: 64,
        seed: 21,
        backend: stream_descriptors::sampling::Backend::Reservoir,
    };
    let a = run_sharded_edges(&parts, &cfg).unwrap();
    let b = run_sharded_edges(&parts, &cfg).unwrap();
    // determinism: the whole sharded pass replays bit-for-bit
    let (sa, sb) = (summary(&a.estimate), summary(&b.estimate));
    for (x, y) in sa.iter().zip(&sb) {
        assert_eq!(x.to_bits(), y.to_bits(), "sharded run is not deterministic");
    }

    // independence: a duplicated stream sampled under one shared seed
    // would yield identical per-shard samples; the derived seeds make a
    // merge of the two shards differ from simply doubling one shard
    let r0 = filled_reservoir(64, &g.edges, 21);
    let r1 = filled_reservoir(64, &g.edges, 21 ^ 0x9e37_79b9_7f4a_7c15);
    assert_ne!(
        r0.edges(),
        r1.edges(),
        "derived shard seeds must give distinct reservoir samples"
    );
    let m0 = MergedReservoir::from_reservoir(&r0, RESERVOIR_MERGE_SEED);
    let m1 = MergedReservoir::from_reservoir(&r1, RESERVOIR_MERGE_SEED);
    let mut both = m0.clone();
    both.merge_state(&m1).unwrap();
    let mut twice = m0.clone();
    twice.merge_state(&m0.clone()).unwrap();
    assert_ne!(both, twice, "distinct RNG streams collapsed to one");
}
