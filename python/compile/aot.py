"""AOT pipeline: lower every L2 graph to HLO text + write the manifest.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and README.md there.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .graphlets import NAMES, ORDERS, overlap_inverse, overlap_matrix
from .kernels.psi import J_GRID


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """name -> (fn, input ShapeDtypeStructs, output shapes for the manifest)."""
    m = model
    return {
        "gabe_finalize": (
            m.gabe_finalize,
            (f32(m.GABE_B, 17), f32(m.GABE_B)),
            [[m.GABE_B, 17]],
        ),
        "maeve_moments": (
            m.maeve_model,
            (f32(m.MAEVE_B, m.MAEVE_NV, 5), f32(m.MAEVE_B, m.MAEVE_NV)),
            [[m.MAEVE_B, 20]],
        ),
        "santa_psi": (
            m.santa_model,
            (f32(m.SANTA_B, 5), f32(m.SANTA_B)),
            [[m.SANTA_B, 6, 60], [m.SANTA_B, 3, 60], [m.SANTA_B, 2, 60]],
        ),
        "pairwise_dist": (
            m.dist_model,
            (f32(m.DIST_M, m.DIST_D), f32(m.DIST_N, m.DIST_D)),
            [[m.DIST_M, m.DIST_N], [m.DIST_M, m.DIST_N]],
        ),
        "trace_powers": (
            m.trace_model,
            (f32(m.TRACE_N, m.TRACE_N), f32(1)),
            [[5]],
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "jax_version": jax.__version__,
        "j_grid": [float(x) for x in J_GRID],
        "graphlet_names": NAMES,
        "graphlet_orders": [int(x) for x in ORDERS],
        "overlap_matrix": [[int(x) for x in row] for row in overlap_matrix()],
        "overlap_inverse": [[float(x) for x in row] for row in overlap_inverse()],
        "shapes": {
            "gabe_b": model.GABE_B,
            "maeve_b": model.MAEVE_B,
            "maeve_nv": model.MAEVE_NV,
            "santa_b": model.SANTA_B,
            "dist_m": model.DIST_M,
            "dist_n": model.DIST_N,
            "dist_d": model.DIST_D,
            "trace_n": model.TRACE_N,
        },
        "artifacts": {},
    }

    for name, (fn, specs, out_shapes) in artifact_specs().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
            "outputs": out_shapes,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
