"""L2: the jax compute graphs that become AOT artifacts.

Each function here is a fixed-shape jax program calling the L1 Pallas
kernels (interpret=True), lowered once by ``aot.py`` to HLO text and
executed from the rust coordinator via PJRT.  Python never runs on the
stream path: the rust side produces the raw estimates (counts, traces,
per-vertex features) and these graphs finalize them into descriptors and
distance matrices.

Fixed batch shapes (padded by the rust side, see artifacts/manifest.json):

  gabe_finalize   counts (B17, 17), nv (B17,)        -> phi (B17, 17)
  maeve_moments   feats (BM, NV, 5), mask (BM, NV)    -> desc (BM, 20)
  santa_psi       traces (BS, 5), nv (BS,)            -> psi (BS, 6, 60), ...
  pairwise_dist   x (M, D), y (N, D)                  -> can (M, N), euc (M, N)
  trace_powers    lap (NL, NL), nv (1,)               -> traces (5,)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .graphlets import ORDERS, overlap_inverse
from .kernels.distance import pairwise_distances
from .kernels.moments import maeve_moments
from .kernels.psi import santa_psi
from .kernels.traces import trace_powers

# ---- artifact shapes (the padding contract with rust) ----
GABE_B = 64
MAEVE_B = 16
MAEVE_NV = 6144
SANTA_B = 64
DIST_M = 256
DIST_N = 256
DIST_D = 128  # max descriptor dim (FEATHER/SF = 128); smaller ones zero-pad
TRACE_N = 512

_OINV = jnp.asarray(overlap_inverse(), dtype=jnp.float32)
_ORDERS = np.asarray(ORDERS)


def _binom(n: jnp.ndarray, k: int) -> jnp.ndarray:
    """C(n, k) for k in {2,3,4}, elementwise over a float array."""
    out = jnp.ones_like(n)
    for i in range(k):
        out = out * (n - float(i))
    from math import factorial

    return jnp.maximum(out / float(factorial(k)), 1.0)


def gabe_finalize(counts: jnp.ndarray, nv: jnp.ndarray):
    """Estimated non-induced counts -> normalized induced-count descriptor.

    phi_k entries are induced counts divided by C(|V|, k), concatenated for
    k in {2, 3, 4} (paper §4.1); induced counts come from O^{-1} @ H.
    """
    induced = counts @ _OINV.T  # (B, 17)
    norm = jnp.stack(
        [_binom(nv, int(_ORDERS[i])) for i in range(17)], axis=1
    )  # (B, 17)
    return (induced / norm,)


def maeve_model(feats: jnp.ndarray, mask: jnp.ndarray):
    return (maeve_moments(feats, mask),)


def santa_model(traces: jnp.ndarray, nv: jnp.ndarray):
    return santa_psi(traces, nv)


def dist_model(x: jnp.ndarray, y: jnp.ndarray):
    return pairwise_distances(x, y)


def trace_model(lap: jnp.ndarray, nv: jnp.ndarray):
    return (trace_powers(lap, nv),)
