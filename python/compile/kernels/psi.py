"""L1 Pallas kernel: SANTA psi_j evaluation over the j-grid.

SANTA (paper §4.3) finalizes the five estimated Laplacian-power traces
tr(L^0..L^4) into the descriptor

    psi_j = alpha * Re( sum_k (-j beta)^k tr(L^k) / k! )

for 60 log-spaced j in [1e-3, 1] (paper §5.1) and the six variants
{Heat, Wave} x {None, Empty, Complete} (Table 8).  Heat uses all five Taylor
terms; Wave's odd terms are imaginary and drop out (paper §6.1.1), so Wave
uses k in {0, 2, 4}.

The kernel additionally emits the unnormalized Heat partial sums for 3/4/5
Taylor terms and Wave partial sums for 3/5 terms — the series Fig. 4 plots
(normalization cancels in relative error, as the paper notes).

Everything is elementwise over a (BB, 60) grid — VPU-shaped, tiny VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

N_J = 60
J_MIN, J_MAX = 1e-3, 1.0
#: The j-grid baked into the artifact (and mirrored by the rust exact path).
J_GRID = np.logspace(np.log10(J_MIN), np.log10(J_MAX), N_J).astype(np.float32)

N_VARIANTS = 6  # HN, HE, HC, WN, WE, WC
BB = 8  # batch block


def _psi_kernel(tr_ref, nv_ref, j_ref, psi_ref, heat_ref, wave_ref):
    tr = tr_ref[...]  # (BB, 5)
    nv = nv_ref[...]  # (BB, 1)
    j = j_ref[...]  # (1, 60)

    t0, t1, t2, t3, t4 = (tr[:, k][:, None] for k in range(5))
    # Heat partial sums: sum_{k<K} (-j)^k tr_k / k!
    h3 = t0 - j * t1 + j**2 / 2.0 * t2
    h4 = h3 - j**3 / 6.0 * t3
    h5 = h4 + j**4 / 24.0 * t4
    # Wave partial sums: Re sum (-ij)^k tr_k / k! -> even k only.
    w3 = t0 - j**2 / 2.0 * t2
    w5 = w3 + j**4 / 24.0 * t4

    heat_ref[...] = jnp.stack([h3, h4, h5], axis=1)  # (BB, 3, 60)
    wave_ref[...] = jnp.stack([w3, w5], axis=1)  # (BB, 2, 60)

    # Normalizations (Table 8): None, Empty (1/|V|), Complete.
    heat_c = 1.0 + (nv - 1.0) * jnp.exp(-j)
    wave_c = 1.0 + (nv - 1.0) * jnp.cos(j)
    # Guard the complete-wave denominator near its zero crossing; with
    # j <= 1 and nv >= 1 it is strictly positive, but padded rows have nv=0.
    wave_c = jnp.where(jnp.abs(wave_c) > 1e-6, wave_c, 1e-6)
    nv_safe = jnp.maximum(nv, 1.0)
    psi_ref[...] = jnp.stack(
        [h5, h5 / nv_safe, h5 / heat_c, w5, w5 / nv_safe, w5 / wave_c], axis=1
    )  # (BB, 6, 60)


@functools.partial(jax.jit, static_argnames=("interpret",))
def santa_psi(traces: jax.Array, nv: jax.Array, *, interpret: bool = True):
    """Finalize SANTA descriptors from trace estimates.

    Args:
      traces: (B, 5) float32 — estimates of tr(L^0..L^4).
      nv: (B,) float32 — graph orders |V_G| (normalization factors).

    Returns:
      psi: (B, 6, 60) five-term descriptor for variants [HN, HE, HC, WN, WE, WC];
      heat_taylor: (B, 3, 60) unnormalized Heat sums with 3/4/5 terms;
      wave_taylor: (B, 2, 60) unnormalized Wave sums with 3/5 terms.
    """
    b = traces.shape[0]
    assert b % BB == 0, b
    out_shape = (
        jax.ShapeDtypeStruct((b, N_VARIANTS, N_J), jnp.float32),
        jax.ShapeDtypeStruct((b, 3, N_J), jnp.float32),
        jax.ShapeDtypeStruct((b, 2, N_J), jnp.float32),
    )
    return pl.pallas_call(
        _psi_kernel,
        grid=(b // BB,),
        in_specs=[
            pl.BlockSpec((BB, 5), lambda i: (i, 0)),
            pl.BlockSpec((BB, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, N_J), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BB, N_VARIANTS, N_J), lambda i: (i, 0, 0)),
            pl.BlockSpec((BB, 3, N_J), lambda i: (i, 0, 0)),
            pl.BlockSpec((BB, 2, N_J), lambda i: (i, 0, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(traces, nv[:, None], jnp.asarray(J_GRID)[None, :])
