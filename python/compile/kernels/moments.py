"""L1 Pallas kernel: masked statistical moments over per-vertex features.

MAEVE (paper §4.2) aggregates five per-vertex features with four moments
(mean, standard deviation, skewness, excess kurtosis).  The streaming rust
side produces padded per-vertex feature arrays; this kernel reduces them to
the 20-dimensional MAEVE descriptor in one pass per graph.

Layout: the grid iterates over the batch; each step reduces one graph's
(NV, 5) feature block under its (NV, 1) validity mask.  The block is
NV*5*4 bytes (6144*5*4 = 120 KiB) — VMEM-trivial; the reduction is
VPU-shaped.  interpret=True on CPU (see distance.py for why).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_FEATURES = 5
N_MOMENTS = 4  # mean, std, skewness, excess kurtosis


def _moments_kernel(feat_ref, mask_ref, out_ref):
    feats = feat_ref[...][0]  # (NV, 5)
    mask = mask_ref[...][0]  # (NV, 1)
    cnt = jnp.maximum(jnp.sum(mask), 1.0)
    m = mask  # broadcastable (NV, 1)
    mean = jnp.sum(feats * m, axis=0) / cnt  # (5,)
    cen = (feats - mean[None, :]) * m
    m2 = jnp.sum(cen**2, axis=0) / cnt
    m3 = jnp.sum(cen**3, axis=0) / cnt
    m4 = jnp.sum(cen**4, axis=0) / cnt
    std = jnp.sqrt(m2)
    safe2 = jnp.where(m2 > 0.0, m2, 1.0)
    skew = jnp.where(m2 > 0.0, m3 / safe2**1.5, 0.0)
    kurt = jnp.where(m2 > 0.0, m4 / safe2**2 - 3.0, 0.0)
    # (4, 5) -> flat (20,): moment-major [mean(5), std(5), skew(5), kurt(5)]
    out_ref[...] = jnp.stack([mean, std, skew, kurt], axis=0).reshape(1, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def maeve_moments(feats: jax.Array, mask: jax.Array, *, interpret: bool = True):
    """Reduce (B, NV, 5) masked vertex features to (B, 20) MAEVE descriptors.

    Args:
      feats: (B, NV, 5) float32; rows beyond the graph order are padding.
      mask: (B, NV) float32 validity mask (1.0 = real vertex).
    """
    b, nv, nf = feats.shape
    assert nf == N_FEATURES
    return pl.pallas_call(
        _moments_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, nv, nf), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, nv, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N_FEATURES * N_MOMENTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, N_FEATURES * N_MOMENTS), jnp.float32),
        interpret=interpret,
    )(feats, mask[..., None])
