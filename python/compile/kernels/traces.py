"""L1 Pallas kernel: blocked Laplacian square for exact trace computation.

The exact-path reference for SANTA (paper §4.3, Theorem 4) needs
tr(L^k), k in {0..4}, of the dense normalized Laplacian.  With L symmetric,

    tr(L^2) = sum_ij L_ij^2
    tr(L^3) = sum_ij (L @ L)_ij * L_ij
    tr(L^4) = sum_ij (L @ L)_ij^2

so a single blocked matmul L @ L plus elementwise reductions suffices.  The
matmul is the MXU-shaped hot-spot: (BT, BK) x (BK, BT) tiles with an
accumulation grid dimension.  128x128 f32 tiles: 3 * 64 KiB live blocks,
VMEM-trivial; on a real TPU the accumulate loop would be the innermost grid
dim exactly as written.  interpret=True on CPU (see distance.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BT = 128  # output tile edge
BK = 128  # contraction block


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_square(lap: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Blocked L @ L for a square (N, N) matrix, N a multiple of BT/BK."""
    n = lap.shape[0]
    assert lap.shape == (n, n) and n % BT == 0 and n % BK == 0, lap.shape
    grid = (n // BT, n // BT, n // BK)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BT, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BT), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BT, BT), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(lap, lap)


@functools.partial(jax.jit, static_argnames=("interpret",))
def trace_powers(lap: jax.Array, nv: jax.Array, *, interpret: bool = True):
    """tr(L^0..L^4) of a zero-padded dense symmetric Laplacian.

    Args:
      lap: (N, N) float32, rows/cols beyond the graph order zero-padded.
      nv: () or (1,) float32 — the true |V_G| (tr(L^0) of the unpadded L).

    Returns:
      (5,) float32: [|V|, tr(L), tr(L^2), tr(L^3), tr(L^4)].
    """
    l2 = matmul_square(lap, interpret=interpret)
    t0 = jnp.reshape(nv, ())
    t1 = jnp.trace(lap)
    t2 = jnp.sum(lap * lap)
    t3 = jnp.sum(l2 * lap)
    t4 = jnp.sum(l2 * l2)
    return jnp.stack([t0, t1, t2, t3, t4])
