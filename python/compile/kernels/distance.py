"""L1 Pallas kernel: tiled pairwise Canberra + Euclidean distance.

This is the analytics hot-spot of the reproduction: k-NN classification
(paper §6.2) and approximation-error measurement (§6.1) both reduce to
dense pairwise distance matrices over descriptor batches.  The kernel is
tiled so each (BM, D) x (BN, D) block pair fits comfortably in VMEM and the
(BM, BN) output tile is produced in one shot.

TPU adaptation note (DESIGN.md §Hardware-Adaptation): the paper's system is
CPU/MPI; on a TPU this kernel is VPU-bound elementwise work over
(BM, BN, D) broadcasts.  Block sizes are chosen so the 3-D intermediate is
BM*BN*D*4 bytes = 64*64*64*4 = 1 MiB < VMEM.  We run it with
interpret=True on CPU (Mosaic custom-calls cannot execute on the CPU PJRT
plugin) — correctness is what pytest checks; the VMEM budget is recorded in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: output tile is (BM, BN); inputs are (BM, D) and (BN, D).
BM = 64
BN = 64


def _dist_kernel(x_ref, y_ref, can_ref, euc_ref):
    """One (BM, BN) output tile of the Canberra + Euclidean matrices."""
    x = x_ref[...]  # (BM, D)
    y = y_ref[...]  # (BN, D)
    diff = x[:, None, :] - y[None, :, :]  # (BM, BN, D)
    absdiff = jnp.abs(diff)
    denom = jnp.abs(x)[:, None, :] + jnp.abs(y)[None, :, :]
    # Canberra convention: 0/0 contributes 0 (also makes zero-padding of the
    # feature dimension a no-op).
    can = jnp.where(denom > 0.0, absdiff / jnp.where(denom > 0.0, denom, 1.0), 0.0)
    can_ref[...] = jnp.sum(can, axis=-1)
    euc_ref[...] = jnp.sqrt(jnp.sum(diff * diff, axis=-1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_distances(x: jax.Array, y: jax.Array, *, interpret: bool = True):
    """Pairwise (canberra, euclidean) distance matrices via the Pallas kernel.

    Args:
      x: (M, D) float32 descriptor batch; M must be a multiple of BM.
      y: (N, D) float32 descriptor batch; N must be a multiple of BN.

    Returns:
      (canberra, euclidean), each (M, N) float32.
    """
    m, d = x.shape
    n, _ = y.shape
    assert m % BM == 0 and n % BN == 0, (m, n)
    grid = (m // BM, n // BN)
    out_shape = (
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
    )
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
            pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(x, y)
