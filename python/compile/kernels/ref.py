"""Pure-jnp correctness oracles for every Pallas kernel.

pytest (and hypothesis sweeps) assert_allclose each kernel in
``kernels/*.py`` against the functions here; the rust test-suite
cross-checks its own exact path against the AOT artifacts, closing the loop
rust <-> L2 <-> L1 <-> ref.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .psi import J_GRID


def pairwise_distances_ref(x, y):
    """(canberra, euclidean) distance matrices, dense jnp."""
    diff = x[:, None, :] - y[None, :, :]
    absdiff = jnp.abs(diff)
    denom = jnp.abs(x)[:, None, :] + jnp.abs(y)[None, :, :]
    can = jnp.where(denom > 0.0, absdiff / jnp.where(denom > 0.0, denom, 1.0), 0.0)
    return jnp.sum(can, axis=-1), jnp.sqrt(jnp.sum(diff * diff, axis=-1))


def maeve_moments_ref(feats, mask):
    """(B, 20) moment-major [mean, std, skew, excess-kurtosis] x 5 features."""
    m = mask[..., None]  # (B, NV, 1)
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)[:, None]  # (B, 1)
    mean = jnp.sum(feats * m, axis=1) / cnt  # (B, 5)
    cen = (feats - mean[:, None, :]) * m
    m2 = jnp.sum(cen**2, axis=1) / cnt
    m3 = jnp.sum(cen**3, axis=1) / cnt
    m4 = jnp.sum(cen**4, axis=1) / cnt
    std = jnp.sqrt(m2)
    safe2 = jnp.where(m2 > 0.0, m2, 1.0)
    skew = jnp.where(m2 > 0.0, m3 / safe2**1.5, 0.0)
    kurt = jnp.where(m2 > 0.0, m4 / safe2**2 - 3.0, 0.0)
    return jnp.concatenate([mean, std, skew, kurt], axis=1)


def santa_psi_ref(traces, nv):
    """Reference psi finalization; mirrors psi._psi_kernel shapes."""
    j = jnp.asarray(J_GRID)[None, :]
    t = [traces[:, k][:, None] for k in range(5)]
    h3 = t[0] - j * t[1] + j**2 / 2.0 * t[2]
    h4 = h3 - j**3 / 6.0 * t[3]
    h5 = h4 + j**4 / 24.0 * t[4]
    w3 = t[0] - j**2 / 2.0 * t[2]
    w5 = w3 + j**4 / 24.0 * t[4]
    nvc = nv[:, None]
    heat_c = 1.0 + (nvc - 1.0) * jnp.exp(-j)
    wave_c = 1.0 + (nvc - 1.0) * jnp.cos(j)
    wave_c = jnp.where(jnp.abs(wave_c) > 1e-6, wave_c, 1e-6)
    nv_safe = jnp.maximum(nvc, 1.0)
    psi = jnp.stack(
        [h5, h5 / nv_safe, h5 / heat_c, w5, w5 / nv_safe, w5 / wave_c], axis=1
    )
    heat = jnp.stack([h3, h4, h5], axis=1)
    wave = jnp.stack([w3, w5], axis=1)
    return psi, heat, wave


def trace_powers_ref(lap, nv):
    """tr(L^0..L^4) by plain dense matmul."""
    l2 = lap @ lap
    return jnp.stack(
        [
            jnp.reshape(nv, ()),
            jnp.trace(lap),
            jnp.trace(l2),
            jnp.trace(l2 @ lap),
            jnp.trace(l2 @ l2),
        ]
    )


def psi_exact_from_eigs(eigs, nv):
    """Exact NetLSD psi over J_GRID from a full eigenspectrum.

    Used by tests to bound the Taylor-truncation error and by the rust
    cross-check fixtures.  Returns (6, 60) for one graph.
    """
    j = np.asarray(J_GRID)[:, None]  # (60, 1)
    lam = np.asarray(eigs)[None, :]  # (1, n)
    heat = np.exp(-j * lam).sum(axis=1)  # (60,)
    wave = np.cos(j * lam).sum(axis=1)
    heat_c = 1.0 + (nv - 1.0) * np.exp(-j[:, 0])
    wave_c = 1.0 + (nv - 1.0) * np.cos(j[:, 0])
    return np.stack(
        [heat, heat / nv, heat / heat_c, wave, wave / nv, wave / wave_c]
    )
