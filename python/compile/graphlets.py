"""The 17 graphs on at most four vertices and their overlap matrix.

Paper §4.1.1 / Fig. 2: GABE estimates non-induced subgraph counts
H = (|H_G^{F_1}|, ..., |H_G^{F_17}|) and converts them to induced counts via
the overlap matrix O:  H = O @ H_induced, O(i, j) = number of subgraphs of
F_j isomorphic to F_i (same order; 0 otherwise).  O is unit upper
triangular under an edge-count-sorted ordering, hence invertible.

The canonical ordering below is the contract shared with the rust side
(``rust/src/count/overlap.rs``); the AOT manifest embeds both O and O^{-1}
and the rust test-suite recomputes them independently and cross-checks.

Index  name                order  edges
  0    e2   (empty-2)        2      0
  1    edge                  2      1
  2    e3   (empty-3)        3      0
  3    edge+1               3      1
  4    wedge (path-3)        3      2
  5    triangle              3      3
  6    e4   (empty-4)        4      0
  7    edge+2               4      1
  8    two-edges (disjoint)  4      2
  9    wedge+1              4      2
 10    triangle+1           4      3
 11    claw (K1,3)           4      3
 12    path-4                4      3
 13    cycle-4               4      4
 14    paw (tailed tri)      4      4
 15    diamond               4      5
 16    k4                    4      6
"""

from __future__ import annotations

import itertools
from fractions import Fraction

import numpy as np

#: name -> (order, edge list) in the canonical index order above.
GRAPHLETS: list[tuple[str, int, list[tuple[int, int]]]] = [
    ("e2", 2, []),
    ("edge", 2, [(0, 1)]),
    ("e3", 3, []),
    ("edge+1", 3, [(0, 1)]),
    ("wedge", 3, [(0, 1), (1, 2)]),
    ("triangle", 3, [(0, 1), (1, 2), (0, 2)]),
    ("e4", 4, []),
    ("edge+2", 4, [(0, 1)]),
    ("two-edges", 4, [(0, 1), (2, 3)]),
    ("wedge+1", 4, [(0, 1), (1, 2)]),
    ("triangle+1", 4, [(0, 1), (1, 2), (0, 2)]),
    ("claw", 4, [(0, 1), (0, 2), (0, 3)]),
    ("path-4", 4, [(0, 1), (1, 2), (2, 3)]),
    ("cycle-4", 4, [(0, 1), (1, 2), (2, 3), (0, 3)]),
    ("paw", 4, [(0, 1), (1, 2), (0, 2), (0, 3)]),
    ("diamond", 4, [(0, 1), (1, 2), (0, 2), (0, 3), (1, 3)]),
    ("k4", 4, [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]),
]

NAMES = [g[0] for g in GRAPHLETS]
ORDERS = np.array([g[1] for g in GRAPHLETS], dtype=np.int64)
N_GRAPHLETS = len(GRAPHLETS)


def _canon(order: int, edges: frozenset[tuple[int, int]]) -> frozenset:
    """Canonical form of a graph on [0, order) under vertex permutation."""
    best = None
    for perm in itertools.permutations(range(order)):
        relabeled = frozenset(
            (min(perm[u], perm[v]), max(perm[u], perm[v])) for u, v in edges
        )
        key = tuple(sorted(relabeled))
        if best is None or key < best[0]:
            best = (key, relabeled)
    return best[1]


_CANON = {
    i: _canon(order, frozenset((min(u, v), max(u, v)) for u, v in edges))
    for i, (_, order, edges) in enumerate(GRAPHLETS)
}


def overlap_matrix() -> np.ndarray:
    """O(i, j) = #subgraphs of F_j isomorphic to F_i (same order), else 0."""
    o = np.zeros((N_GRAPHLETS, N_GRAPHLETS), dtype=np.int64)
    for j, (_, order_j, edges_j) in enumerate(GRAPHLETS):
        ej = [tuple(sorted(e)) for e in edges_j]
        for subset_size in range(len(ej) + 1):
            for subset in itertools.combinations(ej, subset_size):
                c = _canon(order_j, frozenset(subset))
                for i in range(N_GRAPHLETS):
                    if ORDERS[i] == order_j and _CANON[i] == c:
                        o[i, j] += 1
    return o


def overlap_inverse() -> np.ndarray:
    """Exact rational inverse of the overlap matrix, as float64."""
    o = overlap_matrix()
    n = N_GRAPHLETS
    # Gauss-Jordan over Fractions: O is unit-determinant-free but integer;
    # the inverse is rational and must be exact for the count conversion.
    a = [[Fraction(int(o[r, c])) for c in range(n)] for r in range(n)]
    inv = [[Fraction(int(r == c)) for c in range(n)] for r in range(n)]
    for col in range(n):
        piv = next(r for r in range(col, n) if a[r][col] != 0)
        a[col], a[piv] = a[piv], a[col]
        inv[col], inv[piv] = inv[piv], inv[col]
        p = a[col][col]
        a[col] = [x / p for x in a[col]]
        inv[col] = [x / p for x in inv[col]]
        for r in range(n):
            if r != col and a[r][col] != 0:
                f = a[r][col]
                a[r] = [x - f * y for x, y in zip(a[r], a[col])]
                inv[r] = [x - f * y for x, y in zip(inv[r], inv[col])]
    return np.array([[float(x) for x in row] for row in inv], dtype=np.float64)
