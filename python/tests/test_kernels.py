"""L1 Pallas kernels vs pure-jnp oracles — the core numerics signal.

Hypothesis sweeps shapes and value regimes; fixed-seed cases pin the exact
artifact shapes used by the AOT pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.distance import BM, BN, pairwise_distances
from compile.kernels.moments import maeve_moments
from compile.kernels.psi import BB, J_GRID, santa_psi
from compile.kernels.traces import matmul_square, trace_powers

jax.config.update("jax_enable_x64", False)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- distance
@settings(max_examples=10, deadline=None)
@given(
    mb=st.integers(1, 3),
    nb=st.integers(1, 3),
    d=st.sampled_from([8, 17, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_distance_matches_ref(mb, nb, d, seed):
    r = rng(seed)
    x = r.normal(size=(mb * BM, d)).astype(np.float32)
    y = r.normal(size=(nb * BN, d)).astype(np.float32)
    can, euc = pairwise_distances(jnp.asarray(x), jnp.asarray(y))
    can_r, euc_r = ref.pairwise_distances_ref(jnp.asarray(x), jnp.asarray(y))
    assert_allclose(np.asarray(can), np.asarray(can_r), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(euc), np.asarray(euc_r), rtol=1e-5, atol=1e-5)


def test_distance_zero_padding_is_noop():
    r = rng(0)
    x = r.normal(size=(BM, 16)).astype(np.float32)
    y = r.normal(size=(BN, 16)).astype(np.float32)
    xp = np.zeros((BM, 64), np.float32)
    yp = np.zeros((BN, 64), np.float32)
    xp[:, :16], yp[:, :16] = x, y
    can_a, euc_a = pairwise_distances(jnp.asarray(x), jnp.asarray(y))
    can_b, euc_b = pairwise_distances(jnp.asarray(xp), jnp.asarray(yp))
    assert_allclose(np.asarray(can_a), np.asarray(can_b), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(euc_a), np.asarray(euc_b), rtol=1e-5, atol=1e-6)


def test_distance_identity_diagonal_zero():
    r = rng(1)
    x = r.normal(size=(BM, 32)).astype(np.float32)
    can, euc = pairwise_distances(jnp.asarray(x), jnp.asarray(x))
    assert_allclose(np.diag(np.asarray(can)), np.zeros(BM), atol=1e-6)
    assert_allclose(np.diag(np.asarray(euc)), np.zeros(BM), atol=1e-6)


def test_canberra_known_value():
    # canberra([1, -1, 0], [1, 1, 0]) = 0 + 2/2 + 0 = 1
    x = np.zeros((BM, 3), np.float32)
    y = np.zeros((BN, 3), np.float32)
    x[0] = [1, -1, 0]
    y[0] = [1, 1, 0]
    can, euc = pairwise_distances(jnp.asarray(x), jnp.asarray(y))
    assert_allclose(float(can[0, 0]), 1.0, rtol=1e-6)
    assert_allclose(float(euc[0, 0]), 2.0, rtol=1e-6)


# ---------------------------------------------------------------- moments
@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    nv=st.sampled_from([64, 257, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moments_match_ref(b, nv, seed):
    r = rng(seed)
    feats = r.normal(size=(b, nv, 5)).astype(np.float32) * 10.0
    mask = (r.random((b, nv)) < 0.8).astype(np.float32)
    mask[:, 0] = 1.0  # at least one valid vertex
    got = maeve_moments(jnp.asarray(feats), jnp.asarray(mask))
    want = ref.maeve_moments_ref(jnp.asarray(feats), jnp.asarray(mask))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moments_scipy_semantics():
    """Moment-major layout; skew/kurt match the standard definitions."""
    nv = 128
    vals = rng(7).normal(size=nv).astype(np.float32)
    feats = np.zeros((1, nv, 5), np.float32)
    feats[0, :, 2] = vals
    mask = np.ones((1, nv), np.float32)
    out = np.asarray(maeve_moments(jnp.asarray(feats), jnp.asarray(mask)))[0]
    mean, std = vals.mean(), vals.std()
    m2 = ((vals - mean) ** 2).mean()
    m3 = ((vals - mean) ** 3).mean()
    m4 = ((vals - mean) ** 4).mean()
    assert_allclose(out[2], mean, rtol=1e-4, atol=1e-4)  # mean block
    assert_allclose(out[5 + 2], std, rtol=1e-4, atol=1e-4)  # std block
    assert_allclose(out[10 + 2], m3 / m2**1.5, rtol=1e-3, atol=1e-3)
    assert_allclose(out[15 + 2], m4 / m2**2 - 3.0, rtol=1e-3, atol=1e-3)


def test_moments_constant_feature_zero_higher_moments():
    feats = np.full((1, 64, 5), 3.0, np.float32)
    mask = np.ones((1, 64), np.float32)
    out = np.asarray(maeve_moments(jnp.asarray(feats), jnp.asarray(mask)))[0]
    assert_allclose(out[:5], 3.0, rtol=1e-6)
    assert_allclose(out[5:], 0.0, atol=1e-5)


# ---------------------------------------------------------------- psi
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_psi_matches_ref(b, seed):
    r = rng(seed)
    n = b * BB
    nv = r.integers(5, 2000, size=n).astype(np.float32)
    # plausible trace magnitudes: tr(L^0)=|V|, tr(L)=|V|, others O(|V|)
    traces = np.stack(
        [nv, nv, nv * r.random(n) * 2, nv * r.normal(size=n), nv * r.random(n) * 3],
        axis=1,
    ).astype(np.float32)
    got = santa_psi(jnp.asarray(traces), jnp.asarray(nv))
    want = ref.santa_psi_ref(jnp.asarray(traces), jnp.asarray(nv))
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-4)


def test_psi_taylor_converges_to_exact_for_small_j():
    """5-term Taylor vs exact spectrum psi: tight at j<=0.1 (paper Fig. 4)."""
    r = rng(3)
    n = 40
    a = (r.random((n, n)) < 0.2).astype(np.float64)
    a = np.triu(a, 1)
    a = a + a.T
    d = a.sum(1)
    d[d == 0] = 1.0
    dm = np.diag(1.0 / np.sqrt(d))
    lap = np.eye(n) - dm @ a @ dm
    eigs = np.linalg.eigvalsh(lap)
    traces = np.array(
        [[n, np.trace(lap), *(np.trace(np.linalg.matrix_power(lap, k)) for k in (2, 3, 4))]],
        dtype=np.float32,
    )
    traces = np.repeat(traces, BB, axis=0)
    nv = np.full(BB, n, np.float32)
    psi, _, _ = santa_psi(jnp.asarray(traces), jnp.asarray(nv))
    exact = ref.psi_exact_from_eigs(eigs, float(n))  # (6, 60)
    small = J_GRID <= 0.1
    rel = np.abs(np.asarray(psi)[0, 0, small] - exact[0, small]) / np.abs(
        exact[0, small]
    )
    assert rel.max() < 1e-3


# ---------------------------------------------------------------- traces
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_matmul_square_matches_ref(seed):
    r = rng(seed)
    lap = r.normal(size=(256, 256)).astype(np.float32)
    lap = (lap + lap.T) / 2
    got = matmul_square(jnp.asarray(lap))
    assert_allclose(np.asarray(got), lap @ lap, rtol=1e-3, atol=1e-3)


def test_trace_powers_matches_dense():
    r = rng(11)
    n_real = 100
    a = (r.random((n_real, n_real)) < 0.1).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    d = a.sum(1)
    d[d == 0] = 1.0
    dm = np.diag(1.0 / np.sqrt(d)).astype(np.float32)
    lap_small = (np.eye(n_real, dtype=np.float32) - dm @ a @ dm).astype(np.float32)
    lap = np.zeros((512, 512), np.float32)
    lap[:n_real, :n_real] = lap_small
    got = np.asarray(trace_powers(jnp.asarray(lap), jnp.asarray([float(n_real)])))
    want = np.asarray(
        ref.trace_powers_ref(jnp.asarray(lap_small), jnp.asarray(float(n_real)))
    )
    assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_trace_powers_zero_padding_invariant():
    """Padding rows with zeros must not change tr(L^k) for k >= 1."""
    r = rng(13)
    m = 64
    lap_small = r.normal(size=(m, m)).astype(np.float32)
    lap_small = (lap_small + lap_small.T) / 2
    for pad in (128, 512):
        lap = np.zeros((pad, pad), np.float32)
        lap[:m, :m] = lap_small
        if pad % 128 == 0:
            got = np.asarray(
                trace_powers(jnp.asarray(lap), jnp.asarray([float(m)]))
            )
            want = np.asarray(
                ref.trace_powers_ref(jnp.asarray(lap_small), jnp.asarray(float(m)))
            )
            assert_allclose(got, want, rtol=1e-3, atol=1e-2)
