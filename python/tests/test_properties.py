"""Property-style invariants of the L1 kernels beyond point comparisons."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.distance import BM, BN, pairwise_distances
from compile.kernels.moments import maeve_moments
from compile.kernels.psi import BB, J_GRID, santa_psi
from compile.kernels.traces import matmul_square


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([4, 33, 128]))
def test_distance_symmetry_and_triangle_inequality(seed, d):
    r = np.random.default_rng(seed)
    x = r.normal(size=(BM, d)).astype(np.float32)
    can, euc = pairwise_distances(jnp.asarray(x), jnp.asarray(x))
    can, euc = np.asarray(can), np.asarray(euc)
    assert_allclose(can, can.T, atol=1e-5)
    assert_allclose(euc, euc.T, atol=1e-4)
    # euclidean triangle inequality on a probe triple
    i, j, k = 0, BM // 2, BM - 1
    assert euc[i, k] <= euc[i, j] + euc[j, k] + 1e-3


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_distance_scale_invariance_of_canberra(seed):
    """Canberra is invariant to positive rescaling of both vectors."""
    r = np.random.default_rng(seed)
    x = np.abs(r.normal(size=(BM, 16))).astype(np.float32) + 0.1
    y = np.abs(r.normal(size=(BN, 16))).astype(np.float32) + 0.1
    can1, _ = pairwise_distances(jnp.asarray(x), jnp.asarray(y))
    can2, _ = pairwise_distances(jnp.asarray(3.0 * x), jnp.asarray(3.0 * y))
    assert_allclose(np.asarray(can1), np.asarray(can2), rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_moments_permutation_invariant(seed):
    """Moments must not depend on vertex order."""
    r = np.random.default_rng(seed)
    nv = 256
    feats = r.normal(size=(1, nv, 5)).astype(np.float32)
    mask = np.ones((1, nv), np.float32)
    perm = r.permutation(nv)
    a = maeve_moments(jnp.asarray(feats), jnp.asarray(mask))
    b = maeve_moments(jnp.asarray(feats[:, perm]), jnp.asarray(mask))
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_psi_heat_decreases_in_j_for_positive_spectrum():
    """For traces of a PSD Laplacian, the heat sum decreases with j."""
    nv = 50.0
    # traces of eigenvalues all equal 1: tr(L^k) = nv
    traces = np.full((BB, 5), nv, np.float32)
    psi, _, _ = santa_psi(jnp.asarray(traces), jnp.asarray(np.full(BB, nv, np.float32)))
    heat = np.asarray(psi)[0, 0]  # HN variant
    assert np.all(np.diff(heat) < 0), "heat trace must decay in j"
    # j→0 limit is nv
    assert abs(heat[0] - nv) / nv < 5e-3


def test_matmul_square_idempotent_on_projection():
    """P @ P == P for a projection matrix survives the blocked kernel."""
    n = 256
    p = np.zeros((n, n), np.float32)
    p[:8, :8] = np.eye(8)
    got = np.asarray(matmul_square(jnp.asarray(p)))
    assert_allclose(got, p, atol=1e-6)


def test_j_grid_matches_manifest_contract():
    assert len(J_GRID) == 60
    assert abs(J_GRID[0] - 1e-3) < 1e-9
    assert abs(J_GRID[-1] - 1.0) < 1e-6
    ratios = J_GRID[1:] / J_GRID[:-1]
    assert np.allclose(ratios, ratios[0], rtol=1e-4)
