"""L2 model graphs + AOT lowering: shapes, finalization semantics, manifest."""

from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.aot import artifact_specs, to_hlo_text
from compile.graphlets import GRAPHLETS, NAMES, ORDERS, overlap_matrix, overlap_inverse


def test_every_artifact_lowers_to_parsable_hlo():
    for name, (fn, specs, out_shapes) in artifact_specs().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_artifact_outputs_match_manifest_shapes():
    for name, (fn, specs, out_shapes) in artifact_specs().items():
        outs = jax.eval_shape(fn, *specs)
        got = [list(o.shape) for o in outs]
        assert got == out_shapes, (name, got, out_shapes)


def test_gabe_finalize_recovers_known_induced_counts():
    """Feed exact non-induced counts of a triangle graph; induced counts and
    normalization must match hand computation."""
    # Triangle K3: |V|=3, H = [C(3,2)=3 pairs, 3 edges, C(3,3)=1, |E|(|V|-2)=3,
    # wedges=3, triangles=1, zeros for order-4].
    counts = np.zeros((model.GABE_B, 17), np.float32)
    counts[0, :6] = [3, 3, 1, 3, 3, 1]
    nv = np.zeros(model.GABE_B, np.float32)
    nv[0] = 3
    (phi,) = model.gabe_finalize(jnp.asarray(counts), jnp.asarray(nv))
    phi = np.asarray(phi)[0]
    # Induced: e2 = 3 - 3 = 0; edge = 3; e3 = 1 - 3 + 2*3 - ... use O^-1.
    o = overlap_matrix().astype(np.float64)
    induced = np.linalg.solve(o, counts[0].astype(np.float64))
    want = induced.copy()
    want[:2] /= 3.0  # C(3,2)
    want[2:6] /= 1.0  # C(3,3)
    want[6:] /= 1.0  # C(3,4) = 0 -> clamped to 1 in the model
    assert_allclose(phi, want.astype(np.float32), rtol=1e-5, atol=1e-5)
    # Sanity: the only induced order-3 subgraph of K3 is the triangle itself.
    assert_allclose(induced[2:6], [0, 0, 0, 1], atol=1e-9)


def test_overlap_matrix_unit_upper_triangular_per_order():
    o = overlap_matrix()
    assert np.all(np.diag(o) == 1)
    # Entries below the diagonal are zero under the canonical ordering.
    assert np.all(np.tril(o, -1) == 0)
    # Same-order blocks only.
    for i, j in itertools.product(range(17), range(17)):
        if ORDERS[i] != ORDERS[j]:
            assert o[i, j] == 0, (NAMES[i], NAMES[j])


def test_overlap_known_columns():
    o = overlap_matrix()
    k4 = NAMES.index("k4")
    assert o[NAMES.index("wedge+1"), k4] == 12
    assert o[NAMES.index("path-4"), k4] == 12
    assert o[NAMES.index("cycle-4"), k4] == 3
    assert o[NAMES.index("diamond"), k4] == 6
    assert o[NAMES.index("claw"), k4] == 4
    assert o[NAMES.index("triangle+1"), k4] == 4
    tri = NAMES.index("triangle")
    assert o[NAMES.index("wedge"), tri] == 3
    assert o[NAMES.index("edge+1"), tri] == 3


def test_overlap_inverse_is_exact():
    o = overlap_matrix().astype(np.float64)
    oi = overlap_inverse()
    assert_allclose(o @ oi, np.eye(17), atol=1e-9)


def test_graphlet_edge_lists_are_valid():
    for name, order, edges in GRAPHLETS:
        for u, v in edges:
            assert 0 <= u < order and 0 <= v < order and u != v, name
        # no duplicate edges
        norm = {(min(u, v), max(u, v)) for u, v in edges}
        assert len(norm) == len(edges), name


def test_maeve_model_handles_full_padding_batch():
    feats = np.zeros((model.MAEVE_B, model.MAEVE_NV, 5), np.float32)
    mask = np.zeros((model.MAEVE_B, model.MAEVE_NV), np.float32)
    (out,) = model.maeve_model(jnp.asarray(feats), jnp.asarray(mask))
    assert np.all(np.isfinite(np.asarray(out)))
