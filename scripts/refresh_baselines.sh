#!/usr/bin/env sh
# Refresh the checked-in bench-gate baselines from a CI artifact.
#
# Usage: scripts/refresh_baselines.sh <artifact-dir>
#
# <artifact-dir> is an unpacked `bench-gate-json` artifact from a healthy
# run on main (DESIGN.md §5).  The script copies every family that has a
# checked-in baseline, so the two gates never drift apart — refresh both
# or neither.  Review the diff and commit it: the diff *is* the perf
# trajectory change.
set -eu

if [ $# -ne 1 ] || [ ! -d "$1" ]; then
    echo "usage: $0 <dir-with-BENCH_*.json>" >&2
    exit 2
fi
src=$1
dst=$(dirname "$0")/../benches/baselines

# refuse before touching anything: a partial refresh is exactly the
# baseline skew this script exists to prevent
for base in "$dst"/*.json; do
    family=$(basename "$base" .json)
    if [ ! -f "$src/BENCH_$family.json" ]; then
        echo "error: $src/BENCH_$family.json missing (partial refresh refused)" >&2
        exit 1
    fi
done

for base in "$dst"/*.json; do
    family=$(basename "$base" .json)
    cp "$src/BENCH_$family.json" "$base"
    echo "refreshed $base"
done
