//! Massive-network streaming through the master/worker coordinator —
//! a single Table 16-style row with live throughput reporting (§6.3).
//!
//! ```bash
//! cargo run --release --example massive_stream -- CS 0.05 8
//! #                                               net scale workers
//! ```

use stream_descriptors::analyze::canberra;
use stream_descriptors::coordinator::{
    run_pipeline, CoordinatorConfig, DescriptorKind, WorkerEstimate,
};
use stream_descriptors::exact;
use stream_descriptors::gen::massive::{massive_graph, MassiveKind};
use stream_descriptors::graph::stream::VecStream;

fn main() {
    let mut args = std::env::args().skip(1);
    let kind: MassiveKind = args
        .next()
        .unwrap_or_else(|| "CS".into())
        .parse()
        .expect("net name");
    let scale: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let workers: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);

    println!("generating {} at scale {scale}…", kind.name());
    let g = massive_graph(kind, scale, 7);
    let (pv, pe) = kind.paper_size();
    println!(
        "|V|={} |E|={} (paper-scale: |V|={pv} |E|={pe})",
        g.n,
        g.m()
    );

    let budget = (g.m() / 10).clamp(1_000, 500_000);
    let cfg = CoordinatorConfig {
        workers,
        budget,
        chunk_size: 8192,
        queue_depth: 8,
        seed: 7,
        ..Default::default()
    };
    println!("streaming GABE with {workers} workers, b={budget}…");
    let mut s = VecStream::shuffled(g.edges.clone(), 7);
    let r = run_pipeline(&mut s, DescriptorKind::Gabe, &cfg).expect("pipeline");
    println!(
        "processed {} edges in {:.2?} — {:.0} edges/s through {} workers",
        r.edges,
        r.elapsed,
        r.throughput(),
        workers
    );

    let WorkerEstimate::Gabe(avg) = &r.averaged else { unreachable!() };
    println!("computing exact baseline (unbounded-budget pass)…");
    let truth = exact::gabe_exact(&g);
    let dist = canberra(&avg.descriptor(), &truth.descriptor());
    println!("canberra(estimate, exact) = {dist:.4}");
    for (i, name) in stream_descriptors::count::NAMES.iter().enumerate() {
        if stream_descriptors::count::SIZES[i] >= 3 {
            let rel = (avg.counts[i] - truth.counts[i]).abs() / truth.counts[i].max(1.0);
            println!(
                "  {:<10} exact {:>16.0} estimate {:>16.0} rel.err {:.4}",
                name, truth.counts[i], avg.counts[i], rel
            );
        }
    }
}
