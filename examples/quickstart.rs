//! Quickstart: stream one graph through all three descriptors and compare
//! against the exact baselines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use stream_descriptors::analyze::{canberra, euclidean};
use stream_descriptors::descriptors::psi::psi_from_traces;
use stream_descriptors::descriptors::santa::SantaEstimator;
use stream_descriptors::descriptors::{gabe::GabeEstimator, maeve::MaeveEstimator};
use stream_descriptors::exact;
use stream_descriptors::gen;
use stream_descriptors::graph::stream::VecStream;
use stream_descriptors::util::rng::Pcg64;

fn main() -> stream_descriptors::Result<()> {
    let seed = 42;
    let g = gen::powerlaw_cluster_graph(20_000, 4, 0.3, &mut Pcg64::seed_from_u64(seed));
    println!("graph: |V|={} |E|={} (Holme–Kim power-law cluster)", g.n, g.m());

    let gabe_exact = exact::gabe_exact(&g).descriptor();
    let maeve_exact = exact::maeve_exact(&g).descriptor();
    let santa_ref = exact::santa_exact(&g);
    let psi_exact = psi_from_traces(&santa_ref.traces, santa_ref.nv as f64);

    for frac in [0.1, 0.25, 0.5] {
        let b = (g.m() as f64 * frac) as usize;

        let mut s = VecStream::shuffled(g.edges.clone(), seed);
        let gabe = GabeEstimator::new(b).with_seed(seed).run(&mut s);
        let gabe_err = canberra(&gabe.descriptor(), &gabe_exact);

        let mut s = VecStream::shuffled(g.edges.clone(), seed ^ 1);
        let maeve = MaeveEstimator::new(b).with_seed(seed).run(&mut s);
        let maeve_err = canberra(&maeve.descriptor(), &maeve_exact);

        let mut s = VecStream::shuffled(g.edges.clone(), seed ^ 2);
        let santa = SantaEstimator::new(b).with_seed(seed).run(&mut s);
        let psi = psi_from_traces(&santa.traces, santa.nv as f64);
        let santa_err = euclidean(&psi[2], &psi_exact[2]); // HC variant

        println!(
            "b = {frac:>4}·|E|  GABE canberra {gabe_err:8.4}   MAEVE canberra \
             {maeve_err:8.4}   SANTA-HC l2 {santa_err:8.5}"
        );
    }

    // Optional: finalize through the PJRT artifacts (L2/L1 path).
    match stream_descriptors::runtime::Runtime::load_default() {
        Ok(rt) => {
            let mut s = VecStream::shuffled(g.edges.clone(), seed ^ 3);
            let est = GabeEstimator::new(g.m() / 4).with_seed(seed).run(&mut s);
            let phi = rt.gabe_finalize(&[est.counts], &[est.nv as f64])?;
            println!("\nL2-finalized GABE φ (PJRT, {}): {:?}", rt.platform(), &phi[0][..4]);
        }
        Err(e) => println!("\n(skipping PJRT finalization: {e}; run `make artifacts`)"),
    }
    Ok(())
}
