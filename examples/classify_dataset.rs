//! **End-to-end driver** (DESIGN.md §4): run the full three-layer pipeline
//! on a real small classification workload and report the paper's headline
//! metric (1-NN accuracy, paper §6.2).
//!
//! The pipeline exercised here:
//!   L3  synthetic DD-like dataset → shuffled edge streams → reservoir
//!       estimators (GABE counts, SANTA traces) in parallel
//!   L2  PJRT artifacts finalize the estimates (`gabe_finalize`,
//!       `santa_psi`) in fixed-shape batches
//!   L1  the tiled Pallas distance kernel produces the k-NN distance matrix
//!   L3  10×10-fold cross-validated nearest-neighbor classification
//!
//! ```bash
//! make artifacts && cargo run --release --example classify_dataset
//! ```

use std::time::Instant;

use stream_descriptors::classify::{cross_validate, DistanceMatrix, Metric};
use stream_descriptors::descriptors::psi::N_J;
use stream_descriptors::descriptors::santa::SantaEstimator;
use stream_descriptors::descriptors::gabe::GabeEstimator;
use stream_descriptors::gen::datasets::make_dataset;
use stream_descriptors::graph::stream::VecStream;
use stream_descriptors::runtime::Runtime;
use stream_descriptors::util::par::par_map;

fn main() -> stream_descriptors::Result<()> {
    let seed = 11u64;
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let ds = make_dataset("DD", scale, seed);
    println!(
        "dataset: DD-like, {} graphs / {} classes (max |V| {}, max |E| {})",
        ds.len(),
        ds.n_classes,
        ds.max_order(),
        ds.max_size()
    );
    let runtime = Runtime::load_default().ok();
    if runtime.is_none() {
        println!("note: artifacts missing — L2/L1 steps fall back to rust mirrors");
    }

    // ---- L3: streaming estimation at budget |E|/4 ----
    let t0 = Instant::now();
    let raw = par_map(&ds.graphs, 0, |gi, g| {
        let b = (g.m() / 4).max(2);
        let s1 = seed ^ (gi as u64) << 3;
        let mut s = VecStream::shuffled(g.edges.clone(), s1);
        let gabe = GabeEstimator::new(b).with_seed(s1).run(&mut s);
        let mut s = VecStream::shuffled(g.edges.clone(), s1 ^ 1);
        let santa = SantaEstimator::new(b).with_seed(s1).run(&mut s);
        (gabe, santa)
    });
    let stream_time = t0.elapsed();
    let total_edges: usize = ds.graphs.iter().map(|g| g.m()).sum();
    println!(
        "L3 streaming: {} graphs / {} edges in {:.2?} ({:.0} edges/s)",
        ds.len(),
        total_edges,
        stream_time,
        total_edges as f64 / stream_time.as_secs_f64()
    );

    // ---- L2: batched finalization through PJRT ----
    let t0 = Instant::now();
    let (gabe_desc, santa_desc): (Vec<Vec<f64>>, Vec<Vec<f64>>) = match &runtime {
        Some(rt) => {
            let counts: Vec<[f64; 17]> = raw.iter().map(|(g, _)| g.counts).collect();
            let nv: Vec<f64> = raw.iter().map(|(g, _)| g.nv as f64).collect();
            let gabe = rt.gabe_finalize(&counts, &nv)?;
            let traces: Vec<[f64; 5]> = raw.iter().map(|(_, s)| s.traces).collect();
            let snv: Vec<f64> = raw.iter().map(|(_, s)| s.nv as f64).collect();
            let santa = rt
                .santa_psi(&traces, &snv)?
                .into_iter()
                .map(|(psi, _, _)| psi[2 * N_J..3 * N_J].to_vec()) // HC
                .collect();
            (gabe, santa)
        }
        None => (
            raw.iter().map(|(g, _)| g.descriptor().to_vec()).collect(),
            raw.iter()
                .map(|(_, s)| s.descriptor()[2].to_vec())
                .collect(),
        ),
    };
    println!("L2 finalization ({} graphs, batched): {:.2?}",
             ds.len(), t0.elapsed());

    // ---- L1: distance kernel + L3 classification ----
    for (name, descs, metric) in [
        ("GABE@1/4 (canberra)", &gabe_desc, Metric::Canberra),
        ("SANTA-HC@1/4 (l2)", &santa_desc, Metric::Euclidean),
    ] {
        let t0 = Instant::now();
        let dm = match &runtime {
            Some(rt) => {
                let (can, euc) = rt.pairwise_dist(descs, descs)?;
                DistanceMatrix::from_raw(
                    descs.len(),
                    if metric == Metric::Canberra { can } else { euc },
                )
            }
            None => DistanceMatrix::compute(descs, metric),
        };
        let dist_time = t0.elapsed();
        let cv = cross_validate(&dm, &ds.labels, 10, 10, seed);
        println!(
            "{name:<22} accuracy {:.2}% ± {:.2} (distance matrix {:.2?}, {} folds × {} repeats)",
            cv.accuracy, cv.std, dist_time, cv.folds, cv.repeats
        );
    }
    Ok(())
}
