//! §3.4 demo: the variance of the averaged estimate falls like 1/W as
//! workers are added, at constant per-worker budget.
//!
//! ```bash
//! cargo run --release --example worker_scaling
//! ```

use stream_descriptors::coordinator::{
    run_pipeline, CoordinatorConfig, DescriptorKind, WorkerEstimate,
};
use stream_descriptors::count::idx;
use stream_descriptors::exact;
use stream_descriptors::gen;
use stream_descriptors::graph::stream::VecStream;
use stream_descriptors::util::rng::Pcg64;

fn main() {
    let g = gen::powerlaw_cluster_graph(4000, 4, 0.5, &mut Pcg64::seed_from_u64(3));
    let truth = exact::gabe_exact(&g).counts[idx::TRIANGLE];
    let b = g.m() / 4;
    println!(
        "graph |V|={} |E|={}, true triangles {truth:.0}, per-worker b=|E|/4",
        g.n,
        g.m()
    );
    println!("{:>3}  {:>12}  {:>12}  {:>10}  {:>8}", "W", "mean", "variance", "var ratio", "1/W");

    let trials = 16u64;
    let mut base = None;
    for w in [1usize, 2, 4, 8, 16] {
        let vals: Vec<f64> = (0..trials)
            .map(|trial| {
                let cfg = CoordinatorConfig {
                    workers: w,
                    budget: b,
                    chunk_size: 4096,
                    queue_depth: 8,
                    seed: 0x5eed ^ trial << 8 ^ (w as u64) << 32,
                    ..Default::default()
                };
                let mut s = VecStream::shuffled(g.edges.clone(), trial);
                let r = run_pipeline(&mut s, DescriptorKind::Gabe, &cfg).expect("pipeline");
                let WorkerEstimate::Gabe(e) = r.averaged else { unreachable!() };
                e.counts[idx::TRIANGLE]
            })
            .collect();
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let var =
            vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
        let base_var = *base.get_or_insert(var);
        println!(
            "{w:>3}  {mean:>12.1}  {var:>12.1}  {:>10.3}  {:>8.3}",
            var / base_var,
            1.0 / w as f64
        );
    }
}
